//! GPU scheduling policies (§6–§7).
//!
//! Every policy implements [`crate::sim::Policy`] and runs on the same
//! simulator, so comparisons differ only in scheduling decisions:
//!
//! | module            | paper name                           |
//! |-------------------|--------------------------------------|
//! | [`dstack`]        | D-STACK (EDF spatio-temporal + fair opportunistic dynamic pass) |
//! | [`temporal`]      | baseline temporal sharing (SLO-proportional slices @100%) |
//! | [`fixed_batch`]   | FB — fixed batching on default (uncontrolled) CUDA MPS |
//! | [`gslice`]        | GSLICE — static spatial shares at the knee + adaptive batching |
//! | [`triton`]        | Triton-style dynamic batching, temporal execution |
//! | [`max_throughput`]| throughput-maximizing schedule (Fig. 10 upper bound) |
//! | [`max_min`]       | Max-Min fair GPU% allocation (Bertsekas–Gallager) |
//! | [`ideal`]         | §6.2 ideal: kernel-granularity preemptive packing |

pub mod dstack;
pub mod fixed_batch;
pub mod gslice;
pub mod ideal;
pub mod max_min;
pub mod max_throughput;
pub mod temporal;
pub mod triton;

use crate::gpu::{ms_to_us, Us};
use crate::sim::ModelEntry;
use std::collections::VecDeque;

/// Session length: the period of the largest SLO among admitted models
/// (§6.1: "We choose a time period defined by the largest SLO to be a
/// Session").
pub fn session_len_us(models: &[ModelEntry]) -> Us {
    let max_slo = models.iter().map(|m| m.profile.slo_ms).fold(0.0, f64::max);
    ms_to_us(max_slo.max(1.0))
}

/// Scoreboard tracking how many times each model ran in the last few
/// sessions (§6.1.2: "we use a scoreboard that tracks how many times
/// each model has run in the last few (e.g., ten) sessions and
/// prioritizes the models that have run the fewest").
#[derive(Debug, Clone)]
pub struct Scoreboard {
    window: usize,
    /// Per model: run counts for recent sessions (front = current).
    runs: Vec<VecDeque<u64>>,
}

impl Scoreboard {
    pub fn new(n_models: usize, window: usize) -> Scoreboard {
        Scoreboard {
            window: window.max(1),
            runs: (0..n_models).map(|_| VecDeque::from([0])).collect(),
        }
    }

    /// Record that `model` ran once in the current session.
    pub fn record_run(&mut self, model: usize) {
        *self.runs[model].front_mut().unwrap() += 1;
    }

    /// Close the current session and open a new one.
    pub fn end_session(&mut self) {
        for q in &mut self.runs {
            q.push_front(0);
            while q.len() > self.window {
                q.pop_back();
            }
        }
    }

    /// Total runs of `model` over the window (current session included).
    pub fn recent_runs(&self, model: usize) -> u64 {
        self.runs[model].iter().sum()
    }

    /// Model indices sorted fewest-recent-runs first (stable on ties).
    pub fn priority_order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.runs.len()).collect();
        idx.sort_by_key(|&i| (self.recent_runs(i), i));
        idx
    }
}

/// A capacity-reservation timeline over a bounded horizon: a set of
/// `(start, end, pct)` intervals supporting peak-usage queries. Used by
/// D-STACK's planner (static EDF reservations) and its dynamic pass
/// (checking a launch won't steal reserved capacity).
#[derive(Debug, Clone, Default)]
pub struct CapTimeline {
    /// (time, +pct at start / −pct at end) deltas, kept sorted.
    deltas: Vec<(Us, i64)>,
}

impl CapTimeline {
    pub fn new() -> CapTimeline {
        CapTimeline::default()
    }

    pub fn clear(&mut self) {
        self.deltas.clear();
    }

    pub fn add(&mut self, start: Us, end: Us, pct: u32) {
        debug_assert!(start < end);
        self.insert_delta(start, pct as i64);
        self.insert_delta(end, -(pct as i64));
    }

    /// Remove a previously added interval (exact match required).
    pub fn remove(&mut self, start: Us, end: Us, pct: u32) {
        self.remove_delta(start, pct as i64);
        self.remove_delta(end, -(pct as i64));
    }

    fn insert_delta(&mut self, t: Us, d: i64) {
        let pos = self.deltas.partition_point(|&(dt, _)| dt <= t);
        self.deltas.insert(pos, (t, d));
    }

    fn remove_delta(&mut self, t: Us, d: i64) {
        let pos = self
            .deltas
            .iter()
            .position(|&(dt, dd)| dt == t && dd == d)
            .expect("removing interval that was never added");
        self.deltas.remove(pos);
    }

    /// Peak reserved pct over `[t0, t1)`.
    pub fn peak(&self, t0: Us, t1: Us) -> u32 {
        let mut level: i64 = 0;
        let mut i = 0;
        // Level carried into t0: all deltas at times ≤ t0 (interval ends
        // are exclusive, so an interval ending exactly at t0 is gone).
        while i < self.deltas.len() && self.deltas[i].0 <= t0 {
            level += self.deltas[i].1;
            i += 1;
        }
        let mut peak = level;
        while i < self.deltas.len() && self.deltas[i].0 < t1 {
            level += self.deltas[i].1;
            peak = peak.max(level);
            i += 1;
        }
        peak.max(0) as u32
    }

    /// Earliest time `t ∈ [lo, hi]` where an interval `[t, t+dur)` at
    /// `pct` fits under `cap`. Candidate starts are `lo` and every delta
    /// point in range (peak usage only changes there).
    ///
    /// Single sweep with a monotonic deque (sliding-window maximum over
    /// the piecewise-constant usage function) instead of an O(n) peak
    /// query per candidate — the planner/replanner hot path (§Perf).
    pub fn earliest_fit(&self, lo: Us, hi: Us, dur: Us, pct: u32, cap: u32) -> Option<Us> {
        if pct > cap {
            return None;
        }
        let budget = (cap - pct) as i64;
        // Piecewise-constant segments: level l_k on [b_k, b_{k+1}).
        // Build once: O(n).
        let mut bounds: Vec<(Us, i64)> = Vec::with_capacity(self.deltas.len() + 1);
        let mut level = 0i64;
        for &(t, d) in &self.deltas {
            level += d;
            match bounds.last_mut() {
                Some((bt, bl)) if *bt == t => *bl = level,
                _ => bounds.push((t, level)),
            }
        }
        // Candidates ascending: lo, then each boundary in (lo, hi].
        // Maintain a monotonic deque of segment levels intersecting the
        // current window [t, t+dur).
        let seg_level_at = |idx: usize| bounds[idx].1;
        let seg_start = |idx: usize| bounds[idx].0;
        let mut deque: std::collections::VecDeque<usize> = Default::default();
        // j = next segment boundary not yet in the window.
        let mut j = 0usize;
        // Carried level at window start.
        let try_start = |t: Us,
                             deque: &mut std::collections::VecDeque<usize>,
                             j: &mut usize|
         -> bool {
            let end = t + dur;
            // Add segments starting before `end`.
            while *j < bounds.len() && seg_start(*j) < end {
                let l = seg_level_at(*j);
                while deque.back().is_some_and(|&b| seg_level_at(b) <= l) {
                    deque.pop_back();
                }
                deque.push_back(*j);
                *j += 1;
            }
            // Evict segments that ended at or before `t`: a segment k
            // covers [b_k, b_{k+1}); it is stale iff b_{k+1} <= t.
            while deque.front().is_some_and(|&f| {
                bounds.get(f + 1).is_some_and(|&(next, _)| next <= t)
            }) {
                deque.pop_front();
            }
            // Carried level at t = level of the last segment with
            // b_k <= t (the deque front may start later than t).
            let carried = match bounds.partition_point(|&(bt, _)| bt <= t) {
                0 => 0,
                k => bounds[k - 1].1,
            };
            let win_max = deque
                .iter()
                .map(|&k| seg_level_at(k))
                .max()
                .unwrap_or(0)
                .max(carried)
                .max(0);
            win_max <= budget
        };
        if try_start(lo, &mut deque, &mut j) {
            return Some(lo);
        }
        let first = self.deltas.partition_point(|&(t, _)| t <= lo);
        let mut prev = lo;
        for &(t, _) in &self.deltas[first..] {
            if t > hi {
                break;
            }
            if t == prev {
                continue;
            }
            prev = t;
            if try_start(t, &mut deque, &mut j) {
                return Some(t);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::sim::ModelEntry;

    fn entries(names: &[&str]) -> Vec<ModelEntry> {
        names
            .iter()
            .map(|n| {
                let p = by_name(n).unwrap();
                ModelEntry { pct: p.knee_pct, batch: p.opt_batch, profile: p }
            })
            .collect()
    }

    #[test]
    fn session_is_max_slo() {
        let es = entries(&["alexnet", "resnet50", "vgg19"]);
        assert_eq!(session_len_us(&es), 100_000); // vgg19's 100 ms
        let es2 = entries(&["alexnet", "mobilenet"]);
        assert_eq!(session_len_us(&es2), 25_000);
    }

    #[test]
    fn scoreboard_window_and_priority() {
        let mut sb = Scoreboard::new(3, 3);
        sb.record_run(0);
        sb.record_run(0);
        sb.record_run(1);
        assert_eq!(sb.recent_runs(0), 2);
        assert_eq!(sb.priority_order(), vec![2, 1, 0]);
        // Window slides: after 3 new sessions the old runs age out.
        sb.end_session();
        sb.end_session();
        sb.end_session();
        assert_eq!(sb.recent_runs(0), 0);
        assert_eq!(sb.priority_order(), vec![0, 1, 2]);
    }

    #[test]
    fn captimeline_peak() {
        let mut tl = CapTimeline::new();
        tl.add(10, 20, 40);
        tl.add(15, 30, 30);
        assert_eq!(tl.peak(0, 10), 0);
        assert_eq!(tl.peak(10, 15), 40);
        assert_eq!(tl.peak(15, 20), 70);
        assert_eq!(tl.peak(20, 30), 30);
        assert_eq!(tl.peak(0, 100), 70);
        // Query starting mid-interval sees the carried level.
        assert_eq!(tl.peak(17, 18), 70);
        assert_eq!(tl.peak(25, 26), 30);
    }

    #[test]
    fn captimeline_remove() {
        let mut tl = CapTimeline::new();
        tl.add(0, 50, 60);
        tl.add(10, 20, 40);
        tl.remove(10, 20, 40);
        assert_eq!(tl.peak(0, 50), 60);
    }

    #[test]
    #[should_panic(expected = "never added")]
    fn captimeline_remove_unknown_panics() {
        let mut tl = CapTimeline::new();
        tl.remove(0, 1, 10);
    }

    #[test]
    fn captimeline_earliest_fit() {
        let mut tl = CapTimeline::new();
        tl.add(0, 100, 80); // only 20% free until t=100
        // 30% for 50 µs can't fit before t=100.
        assert_eq!(tl.earliest_fit(0, 200, 50, 30, 100), Some(100));
        // 20% fits immediately.
        assert_eq!(tl.earliest_fit(0, 200, 50, 20, 100), Some(0));
        // Nothing fits if the window is too small.
        assert_eq!(tl.earliest_fit(0, 50, 50, 30, 100), None);
    }
}
