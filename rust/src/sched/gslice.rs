//! GSLICE-style static spatial sharing (§2, §7).
//!
//! Each admitted model receives a *static* GPU% slice: its knee if the
//! knees fit, otherwise knees scaled down proportionally so the total is
//! ≤ 100% (the paper's GSLICE pathology: "executing a large number of
//! models potentially causes each model to get a small GPU slice (less
//! than the Knee), leading to higher inference latency"). Batching is
//! adaptive with GSLICE's SLO/2 budget. There is no temporal scheduler:
//! every model independently runs whenever it has work.

use crate::batching::{choose_batch, BatchPolicy};
use crate::sim::{Launch, ModelEntry, Policy, SimView};

#[derive(Debug)]
pub struct Gslice {
    /// Static per-model share (GPU%).
    pub shares: Vec<u32>,
}

impl Gslice {
    /// Compute static shares from the entries' knee GPU%.
    pub fn from_entries(models: &[ModelEntry]) -> Gslice {
        Gslice::from_entries_masked(models, &vec![true; models.len()])
    }

    /// Like [`Self::from_entries`], but control-plane tombstones
    /// (`active[i] == false`) get a zero share and are excluded from the
    /// normalization — retired models must not shrink live ones.
    pub fn from_entries_masked(models: &[ModelEntry], active: &[bool]) -> Gslice {
        let knees: Vec<u32> = models
            .iter()
            .zip(active)
            .map(|(m, &a)| if a { m.profile.knee_pct } else { 0 })
            .collect();
        let total: u32 = knees.iter().sum();
        let shares = if total <= 100 {
            knees
        } else {
            // Scale down proportionally; floor, but at least 1% for
            // every live model.
            knees
                .iter()
                .map(|&k| {
                    if k == 0 {
                        0
                    } else {
                        ((k as f64 * 100.0 / total as f64).floor() as u32).max(1)
                    }
                })
                .collect()
        };
        Gslice { shares }
    }
}

impl Policy for Gslice {
    fn name(&self) -> String {
        "gslice".into()
    }

    fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
        for (i, e) in v.models.iter().enumerate() {
            if v.gpu.n_running_of(i) > 0 {
                continue; // one in-flight batch per model slice
            }
            let queued = v.queue_len(i);
            if queued == 0 {
                continue;
            }
            let share = self.shares[i];
            if share == 0 {
                continue; // retired (tombstone) slice
            }
            if v.gpu.free_pct() < share {
                // Statically unreachable (shares are normalized to ≤ 100
                // with one in-flight batch per slice), but a control-plane
                // reconfiguration can briefly leave an old batch running
                // at a larger, pre-renormalization share.
                continue;
            }
            // GSLICE adaptive batching: fit within half the SLO.
            let budget = e.profile.slo_ms / 2.0;
            let b = choose_batch(
                BatchPolicy::Adaptive,
                &e.profile,
                &v.gpu.spec,
                queued,
                e.batch,
                share,
                Some(budget),
            );
            // Below-knee slices may not fit any batch in the budget; fall
            // back to batch 1 (GSLICE still serves, just slowly).
            let b = if b == 0 { 1 } else { b };
            return vec![Launch { model: i, batch: b, pct: share, latency_ms_override: None }];
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::sim::{entries_at_optimum, Sim, SimConfig};
    use crate::workload::{merged_stream, Arrivals};

    fn entries(names: &[&str]) -> Vec<ModelEntry> {
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        entries_at_optimum(&profiles)
    }

    #[test]
    fn shares_fit_when_knees_fit() {
        // alexnet 30 + resnet50 40 = 70 ≤ 100 → knees unchanged.
        let g = Gslice::from_entries(&entries(&["alexnet", "resnet50"]));
        assert_eq!(g.shares, vec![30, 40]);
    }

    #[test]
    fn shares_scale_down_when_oversubscribed() {
        // Four knees 30+40+50+20 = 140 > 100 → proportional scaling.
        let g = Gslice::from_entries(&entries(&["alexnet", "resnet50", "vgg19", "mobilenet"]));
        let total: u32 = g.shares.iter().sum();
        assert!(total <= 100, "total {total}");
        // VGG-19 is pushed well below its 50% knee.
        assert!(g.shares[2] < 40, "vgg share {}", g.shares[2]);
    }

    #[test]
    fn masked_shares_exclude_tombstones() {
        // vgg19 (50) + resnet50 (40) + alexnet (30) = 120 > 100 → all
        // scaled; masking vgg19 out (a control-plane tombstone) returns
        // the live models to their full knees and zeroes the tombstone.
        let es = entries(&["vgg19", "resnet50", "alexnet"]);
        let all = Gslice::from_entries(&es);
        assert!(all.shares.iter().sum::<u32>() <= 100);
        assert!(all.shares.iter().all(|&s| s > 0));
        let masked = Gslice::from_entries_masked(&es, &[false, true, true]);
        assert_eq!(masked.shares, vec![0, 40, 30]);
    }

    #[test]
    fn concurrent_spatial_execution() {
        let es = entries(&["alexnet", "resnet50"]);
        let specs: Vec<_> = es
            .iter()
            .map(|e| (Arrivals::Poisson { rate: 500.0 }, e.profile.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, 3_000.0, 13);
        let mut pol = Gslice::from_entries(&es);
        let mut sim =
            Sim::new(SimConfig { horizon_ms: 3_000.0, gantt: true, ..Default::default() }, es);
        let rep = sim.run(&mut pol, &reqs);
        for m in &rep.per_model {
            assert!(m.served > 0);
        }
        // Unlike temporal, the two models' Gantt entries overlap in time.
        let gantt = sim.gpu.gantt.as_ref().unwrap();
        let overlap = gantt.iter().enumerate().any(|(i, a)| {
            gantt[i + 1..]
                .iter()
                .any(|b| a.model != b.model && a.start < b.end && b.start < a.end)
        });
        assert!(overlap, "expected spatially concurrent execution");
    }

    #[test]
    fn below_knee_latency_blows_up_with_many_models() {
        // 7-model mix pushes shares far below knees; VGG-19's latency
        // inflates vs its knee runtime (the paper's GSLICE critique).
        let names =
            ["alexnet", "mobilenet", "resnet18", "resnet50", "inception", "resnext50", "vgg19"];
        let es = entries(&names);
        let g = Gslice::from_entries(&es);
        let vgg_idx = 6;
        let vgg = &es[vgg_idx].profile;
        let lat_at_share = vgg.latency_ms(g.shares[vgg_idx], 16);
        assert!(
            lat_at_share > 1.5 * vgg.runtime_ms,
            "expected blow-up: {lat_at_share} vs knee {}",
            vgg.runtime_ms
        );
    }
}
