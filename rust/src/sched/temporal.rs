//! Baseline temporal sharing (§6.1, Fig. 9a).
//!
//! The GPU is given *whole* (100%) to one model at a time, in round-robin
//! time slices proportional to each model's SLO (the paper's setup for
//! the temporal baseline). Batches are assembled adaptively (Clipper /
//! Nexus style) within the remaining slice budget. Switching models
//! costs `switch_ms` of GPU idle time — the paper's "significant cost of
//! frequent switching between applications".

use crate::batching::{choose_batch, BatchPolicy};
use crate::gpu::{ms_to_us, Us};
use crate::sim::{Launch, Policy, SimView};

#[derive(Debug)]
pub struct Temporal {
    /// Slice length per model (µs), proportional to SLO.
    slices: Vec<Us>,
    current: usize,
    slice_end: Us,
    /// GPU unavailable until here (model switch cost).
    ready_at: Us,
    switch_us: Us,
    initialized: bool,
}

impl Temporal {
    pub fn new(slos_ms: &[f64], session_us: Us, switch_ms: f64) -> Temporal {
        let total: f64 = slos_ms.iter().sum();
        let slices = slos_ms
            .iter()
            .map(|s| ((s / total) * session_us as f64).round().max(1.0) as Us)
            .collect();
        Temporal {
            slices,
            current: 0,
            slice_end: 0,
            ready_at: 0,
            switch_us: ms_to_us(switch_ms),
            initialized: false,
        }
    }

    /// Default configuration from the models' SLOs (1 ms switch cost).
    pub fn from_entries(models: &[crate::sim::ModelEntry]) -> Temporal {
        let slos: Vec<f64> = models.iter().map(|m| m.profile.slo_ms).collect();
        let session = super::session_len_us(models);
        Temporal::new(&slos, session, 1.0)
    }

    fn advance_slices(&mut self, now: Us) {
        if !self.initialized {
            self.initialized = true;
            self.slice_end = now + self.slices[0];
            return;
        }
        while now >= self.slice_end {
            self.current = (self.current + 1) % self.slices.len();
            // Switch cost: the GPU idles before the next model may run.
            self.ready_at = self.slice_end + self.switch_us;
            self.slice_end += self.slices[self.current] + self.switch_us;
        }
    }
}

impl Policy for Temporal {
    fn name(&self) -> String {
        "temporal".into()
    }

    fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
        self.advance_slices(v.now);
        if v.gpu.n_running() > 0 || v.now < self.ready_at {
            return Vec::new();
        }
        // Control-plane tombstones own no GPU time: hand their slices to
        // the next live model immediately instead of idling through them
        // (no switch cost — nothing ran).
        let mut hops = 0;
        while hops < self.slices.len() && !v.is_active(self.current) {
            self.current = (self.current + 1) % self.slices.len();
            self.slice_end = v.now + self.slices[self.current];
            hops += 1;
        }
        if hops == self.slices.len() {
            return Vec::new(); // every model is retired
        }
        let m = self.current;
        let entry = &v.models[m];
        let queued = v.queue_len(m);
        if queued == 0 {
            return Vec::new();
        }
        // Budget: the batch must finish within the slice (late requests
        // are still served — lateness shows up as SLO violations).
        let budget = (self.slice_end.saturating_sub(v.now)) as f64 / 1_000.0;
        let b = choose_batch(
            BatchPolicy::Adaptive,
            &entry.profile,
            &v.gpu.spec,
            queued,
            entry.batch,
            100,
            Some(budget),
        );
        // Non-preemptive slice overrun: when no batch fits the remaining
        // slice, the model still runs its (adaptive) batch — a kernel
        // launch cannot be split — and the next slice simply starts late,
        // exactly the switching/overrun cost the paper attributes to
        // temporal sharing.
        let b = if b == 0 { (queued as u32).min(entry.batch) } else { b };
        vec![Launch { model: m, batch: b, pct: 100, latency_ms_override: None }]
    }

    fn next_wakeup(&mut self, v: &SimView) -> Option<Us> {
        // Wake at the next slice boundary (or when the switch completes).
        let t = if v.now < self.ready_at { self.ready_at } else { self.slice_end };
        (t > v.now).then_some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::sim::{entries_at_optimum, Sim, SimConfig};
    use crate::workload::{merged_stream, Arrivals};

    fn run(names: &[&str], rate: f64, horizon_ms: f64) -> crate::metrics::RunReport {
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> =
            profiles.iter().map(|p| (Arrivals::Poisson { rate }, p.slo_ms)).collect();
        let reqs = merged_stream(&specs, horizon_ms, 42);
        let mut pol = Temporal::from_entries(&entries);
        let mut sim = Sim::new(SimConfig { horizon_ms, ..Default::default() }, entries);
        sim.run(&mut pol, &reqs)
    }

    #[test]
    fn slices_proportional_to_slo() {
        let t = Temporal::new(&[25.0, 50.0, 100.0], 175_000, 0.0);
        assert_eq!(t.slices, vec![25_000, 50_000, 100_000]);
    }

    #[test]
    fn serves_all_models_some() {
        let rep = run(&["alexnet", "resnet50", "vgg19"], 200.0, 4_000.0);
        for m in &rep.per_model {
            assert!(m.served > 0, "{} starved entirely", m.name);
        }
    }

    #[test]
    fn one_model_at_a_time() {
        // The invariant is enforced structurally (dispatch refuses while
        // anything runs); spot-check via the Gantt log.
        let profiles = vec![by_name("alexnet").unwrap(), by_name("mobilenet").unwrap()];
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> =
            profiles.iter().map(|p| (Arrivals::Poisson { rate: 400.0 }, p.slo_ms)).collect();
        let reqs = merged_stream(&specs, 2_000.0, 7);
        let mut pol = Temporal::from_entries(&entries);
        let mut sim =
            Sim::new(SimConfig { horizon_ms: 2_000.0, gantt: true, ..Default::default() }, entries);
        sim.run(&mut pol, &reqs);
        let gantt = sim.gpu.gantt.as_ref().unwrap();
        assert!(!gantt.is_empty());
        for w in gantt.windows(2) {
            assert!(w[1].start >= w[0].end, "temporal overlap: {w:?}");
        }
        for e in gantt {
            assert_eq!(e.pct, 100, "temporal always gets the whole GPU");
        }
    }

    #[test]
    fn heavy_models_squeeze_light_ones() {
        // With VGG-19 in the mix, light models get starved relative to
        // running alone — the pathology D-STACK fixes (Fig. 10).
        let with_heavy = run(&["alexnet", "vgg19"], 400.0, 4_000.0);
        let alone = run(&["alexnet"], 400.0, 4_000.0);
        let a_with = with_heavy.per_model[0].served;
        let a_alone = alone.per_model[0].served;
        assert!(
            (a_with as f64) < 0.8 * a_alone as f64,
            "alexnet with vgg: {a_with}, alone: {a_alone}"
        );
    }
}

#[cfg(test)]
mod debug_t4 {
    use super::*;
    use crate::cluster::entries_for_gpu;
    use crate::profile::{by_name, T4};
    use crate::sim::{Sim, SimConfig};
    use crate::workload::{merged_stream, Arrivals};

    #[test]
    #[ignore]
    fn debug_temporal_t4() {
        let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        for p in &profiles {
            eprintln!("{}: L_T4(100,1)={:.1} L_T4(100,16)={:.1} slo={}",
                p.name, p.latency_ms_on(&T4, 100, 1), p.latency_ms_on(&T4, 100, 16), p.slo_ms);
        }
        let entries = entries_for_gpu(&profiles, &T4);
        let specs: Vec<_> = profiles.iter().map(|p| (Arrivals::Poisson { rate: 80.0 }, p.slo_ms)).collect();
        let reqs = merged_stream(&specs, 4_000.0, 9);
        let mut pol = Temporal::from_entries(&entries);
        eprintln!("slices: {:?}", pol.slices);
        let mut sim = Sim::new(SimConfig { gpu: T4.clone(), horizon_ms: 4_000.0, ..Default::default() }, entries);
        let rep = sim.run(&mut pol, &reqs);
        for m in &rep.per_model { eprintln!("{}: served={} batches={}", m.name, m.served, m.batches); }
    }
}
