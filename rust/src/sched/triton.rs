//! Triton-Inference-Server-style scheduling (§1 Table 1, §7).
//!
//! Triton's scheduler performs *dynamic batching* per model (launch when
//! the queue reaches the preferred batch size or the oldest request has
//! waited `max_queue_delay`) but executes models one at a time on the
//! whole GPU (temporal multiplexing): "Models hosted in Triton server
//! too have to multiplex GPU temporally" (§7).

use crate::gpu::{ms_to_us, Us};
use crate::sim::{Launch, ModelEntry, Policy, SimView};

#[derive(Debug)]
pub struct Triton {
    /// Per-model max queue delay before a partial batch is flushed (µs).
    max_queue_delay_us: Vec<Us>,
}

impl Triton {
    /// Default: flush partial batches after SLO/4 (a common Triton
    /// configuration heuristic for latency-sensitive endpoints).
    pub fn from_entries(models: &[ModelEntry]) -> Triton {
        Triton {
            max_queue_delay_us: models
                .iter()
                .map(|m| ms_to_us(m.profile.slo_ms / 4.0))
                .collect(),
        }
    }

    /// A model is ready when a full preferred batch is queued or its
    /// oldest request has exceeded the queue delay.
    fn ready(&self, v: &SimView, i: usize) -> bool {
        let queued = v.queue_len(i) as u32;
        if queued == 0 {
            return false;
        }
        if queued >= v.models[i].batch {
            return true;
        }
        let oldest_arrival = v.queues[i].front().unwrap().arrival;
        v.now.saturating_sub(oldest_arrival) >= self.max_queue_delay_us[i]
    }
}

impl Policy for Triton {
    fn name(&self) -> String {
        "triton".into()
    }

    fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
        if v.gpu.n_running() > 0 {
            return Vec::new(); // temporal: one model batch at a time
        }
        // FCFS across ready models: pick the one whose head waited longest.
        let mut best: Option<(Us, usize)> = None;
        for i in 0..v.models.len() {
            if self.ready(v, i) {
                let head = v.queues[i].front().unwrap().arrival;
                if best.is_none_or(|(h, _)| head < h) {
                    best = Some((head, i));
                }
            }
        }
        let Some((_, i)) = best else { return Vec::new() };
        let b = (v.queue_len(i) as u32).min(v.models[i].profile.max_batch);
        vec![Launch { model: i, batch: b, pct: 100, latency_ms_override: None }]
    }

    fn next_wakeup(&mut self, v: &SimView) -> Option<Us> {
        // Wake when the oldest partial batch hits its queue-delay flush.
        let mut next: Option<Us> = None;
        for i in 0..v.models.len() {
            if let Some(head) = v.queues[i].front() {
                let flush = head.arrival + self.max_queue_delay_us[i];
                if flush > v.now {
                    next = Some(next.map_or(flush, |n| n.min(flush)));
                }
            }
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::sim::{entries_at_optimum, Sim, SimConfig};
    use crate::workload::{merged_stream, Arrivals};

    fn run(names: &[&str], rate: f64, horizon_ms: f64) -> crate::metrics::RunReport {
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> =
            profiles.iter().map(|p| (Arrivals::Poisson { rate }, p.slo_ms)).collect();
        let reqs = merged_stream(&specs, horizon_ms, 33);
        let mut pol = Triton::from_entries(&entries);
        let mut sim = Sim::new(SimConfig { horizon_ms, ..Default::default() }, entries);
        sim.run(&mut pol, &reqs)
    }

    #[test]
    fn partial_batches_flush_at_low_rate() {
        // At 50 req/s a full 16-batch would take 320 ms to form; dynamic
        // batching flushes early, so most requests are served in-SLO.
        let rep = run(&["alexnet"], 50.0, 4_000.0);
        let m = &rep.per_model[0];
        assert!(m.served > 0);
        assert!(m.mean_batch() < 16.0, "mean batch {}", m.mean_batch());
        let ok = m.served_in_slo as f64 / m.offered() as f64;
        assert!(ok > 0.8, "in-SLO fraction {ok}");
    }

    #[test]
    fn batches_grow_at_high_rate() {
        let rep = run(&["alexnet"], 1_500.0, 3_000.0);
        assert!(rep.per_model[0].mean_batch() > 8.0);
    }

    #[test]
    fn temporal_execution_degrades_with_many_models() {
        // Aggregate throughput per model drops as more models multiplex
        // (Fig. 11a: Triton's throughput falls off with model count).
        let two = run(&["resnet50", "vgg19"], 300.0, 4_000.0);
        let four = run(&["resnet50", "vgg19", "alexnet", "mobilenet"], 300.0, 4_000.0);
        let r50_two = two.per_model[0].served;
        let r50_four = four.per_model[0].served;
        assert!(
            r50_four < r50_two,
            "resnet50 should lose throughput with more tenants: {r50_two} -> {r50_four}"
        );
    }
}
