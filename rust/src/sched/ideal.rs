//! The "ideal" scheduler of §6.2: a theoretical upper bound that
//! schedules at the granularity of *individual DNN kernels*, with free
//! preemption, perfect knowledge of each kernel's instantaneous GPU
//! demand, and instantaneous reallocation. The paper uses it to show
//! D-STACK reaches >90% of the achievable throughput/utilization.
//!
//! Implemented as a time-slotted (default 100 µs, the paper's value)
//! packing simulator: in each slot, eligible kernels (the *next* kernel
//! of each in-flight inference — Eq. 14's sequential-execution
//! constraint) are packed EDF-first until the GPU% budget of the slot is
//! exhausted (Eq. 13's objective: maximize Σ GPU% per slot).

use crate::gpu::{ms_to_us, Us};
use crate::profile::{GpuSpec, ModelProfile};

/// One kernel of the decomposed model.
#[derive(Debug, Clone)]
pub struct KernelSeg {
    /// GPU% this kernel can actually use (its per-kernel knee).
    pub pct: u32,
    /// Execution time at that GPU% (µs).
    pub dur_us: Us,
}

/// Decompose a profile into per-kernel segments using its calibrated
/// analytic model at batch `b`: kernel `i` demands
/// `min(N_i, SMs)/SMs` of the GPU and runs for `E_i + t_np` time units.
pub fn decompose(m: &ModelProfile, gpu: &GpuSpec, b: u32) -> Vec<KernelSeg> {
    let dnn = &m.dnn;
    let total_sms = gpu.sms as f64;
    let mut raw: Vec<(u32, f64)> = Vec::with_capacity(dnn.kmax);
    let mut sum_units = 0.0;
    for i in 0..dnn.kmax {
        let n_i = dnn.n_i(i, b as f64);
        let used_sms = n_i.min(total_sms).max(1.0);
        let pct = ((used_sms / total_sms) * 100.0).ceil().max(1.0) as u32;
        let e_i = n_i * dnn.t_p / used_sms; // Eq. 2 at the kernel's knee
        let units = e_i + dnn.t_np;
        sum_units += units;
        raw.push((pct.min(100), units));
    }
    // NB: per-kernel durations are at each kernel's own knee, so the
    // sequential total is shorter than the whole-model knee runtime —
    // exactly the ideal scheduler's assumed superpower (instantaneous
    // per-kernel right-sizing). No further normalization.
    let _ = sum_units;
    raw.into_iter()
        .map(|(pct, units)| KernelSeg {
            pct,
            dur_us: ms_to_us(units * dnn.ms_per_unit / gpu.rel_capacity).max(1),
        })
        .collect()
}

/// Result of an ideal-scheduler run.
#[derive(Debug, Clone)]
pub struct IdealReport {
    /// Completed inferences (batches) per model.
    pub completions: Vec<u64>,
    /// Items (images) per second per model.
    pub throughput: Vec<f64>,
    /// Mean GPU utilization 0..1.
    pub utilization: f64,
}

struct Job {
    model: usize,
    deadline: Us,
    kernel: usize,
    remaining_us: Us,
}

/// Run the ideal kernel-granularity preemptive scheduler, closed-loop
/// (every model always has its next batch ready — §6.2 measures
/// saturated throughput/utilization).
pub fn run_ideal(
    models: &[ModelProfile],
    gpu: &GpuSpec,
    batch: u32,
    horizon_ms: f64,
    slot_us: Us,
) -> IdealReport {
    let horizon = ms_to_us(horizon_ms);
    let segs: Vec<Vec<KernelSeg>> = models.iter().map(|m| decompose(m, gpu, batch)).collect();
    let slos: Vec<Us> = models.iter().map(|m| ms_to_us(m.slo_ms)).collect();

    let mut jobs: Vec<Job> = models
        .iter()
        .enumerate()
        .map(|(j, _)| Job {
            model: j,
            deadline: slos[j],
            kernel: 0,
            remaining_us: segs[j][0].dur_us,
        })
        .collect();
    let mut completions = vec![0u64; models.len()];
    let mut used_integral = 0f64;

    let mut t: Us = 0;
    while t < horizon {
        // EDF eligibility order (stable by model index on ties).
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by_key(|&i| (jobs[i].deadline, jobs[i].model));
        let mut cap = 100u32;
        let mut progressed: Vec<usize> = Vec::new();
        for &i in &order {
            let pct = segs[jobs[i].model][jobs[i].kernel].pct;
            // A kernel may use `pct`; if less is free it can still run on
            // the remaining SMs (it simply advances slower). The ideal
            // scheduler exploits this perfectly.
            if cap == 0 {
                break;
            }
            let granted = pct.min(cap);
            cap -= granted;
            progressed.push(i);
            // Progress scaled by granted/needed (fewer SMs → slower).
            let speed = granted as f64 / pct as f64;
            let adv = (slot_us as f64 * speed).round() as Us;
            let j = &mut jobs[i];
            j.remaining_us = j.remaining_us.saturating_sub(adv);
        }
        used_integral += (100 - cap) as f64 * slot_us as f64;
        // Kernel / inference completions.
        for j in jobs.iter_mut() {
            while j.remaining_us == 0 {
                j.kernel += 1;
                if j.kernel >= segs[j.model].len() {
                    completions[j.model] += 1;
                    j.kernel = 0;
                    j.deadline = t + slot_us + slos[j.model];
                }
                j.remaining_us = segs[j.model][j.kernel].dur_us;
            }
        }
        t += slot_us;
    }

    let horizon_s = horizon_ms / 1_000.0;
    let throughput = completions
        .iter()
        .map(|&c| c as f64 * batch as f64 / horizon_s)
        .collect();
    IdealReport {
        completions,
        throughput,
        utilization: used_integral / (100.0 * horizon as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::{convnets, V100};

    #[test]
    fn decomposition_covers_model_runtime() {
        let cs = convnets();
        for m in &cs {
            let segs = decompose(m, &V100, 16);
            assert_eq!(segs.len(), m.dnn.kmax);
            let total_ms: f64 = segs.iter().map(|s| s.dur_us as f64 / 1_000.0).sum();
            // Per-kernel-knee total is ≤ the whole-model knee runtime
            // (each kernel gets its own right-sized allocation) but the
            // same order of magnitude.
            assert!(
                total_ms > 0.3 * m.runtime_ms && total_ms <= 1.2 * m.runtime_ms,
                "{}: decomposed {total_ms} vs runtime {}",
                m.name,
                m.runtime_ms
            );
            // Early kernels demand more GPU than late ones (Eq. 1).
            assert!(segs[0].pct >= segs[segs.len() - 1].pct);
        }
    }

    #[test]
    fn ideal_achieves_high_utilization() {
        // §6.2/Fig. 9d: the ideal scheduler reaches ≈95% utilization on
        // the 3-ConvNet mix.
        let cs = convnets();
        let rep = run_ideal(&cs, &V100, 16, 2_000.0, 100);
        assert!(rep.utilization > 0.90, "utilization {}", rep.utilization);
        for (j, c) in rep.completions.iter().enumerate() {
            assert!(*c > 0, "convnet{} never completed", j + 1);
        }
    }

    #[test]
    fn utilization_bounded_by_one() {
        let cs = convnets();
        let rep = run_ideal(&cs, &V100, 16, 500.0, 100);
        assert!(rep.utilization <= 1.0 + 1e-9);
        assert!(rep.throughput.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn single_model_utilization_near_its_mean_demand() {
        // One ConvNet alone can't fill the GPU: utilization ≈ its own
        // average kernel demand, well below 1.
        let cs = vec![convnets().remove(0)];
        let rep = run_ideal(&cs, &V100, 16, 1_000.0, 100);
        assert!(rep.utilization < 0.9, "{}", rep.utilization);
    }
}
