//! Max-Min fair scheduler (§6.3, Fig. 10): classic progressive-filling
//! allocation of GPU% (Bertsekas & Gallager, *Data Networks*): demands
//! are the models' knee GPU%; the smallest demands are satisfied first,
//! and any remaining capacity is split equally among unsatisfied models.
//! Models then run concurrently inside their static allocations.

use crate::batching::{choose_batch, BatchPolicy};
use crate::sim::{Launch, ModelEntry, Policy, SimView};

/// Progressive-filling max-min allocation: each demand `d_i` receives
/// `min(d_i, fair share)` where the fair share is raised until capacity
/// is exhausted. Returns per-model GPU%.
pub fn max_min_allocation(demands: &[u32], capacity: u32) -> Vec<u32> {
    let n = demands.len();
    if n == 0 {
        return Vec::new();
    }
    let mut alloc = vec![0u32; n];
    let mut remaining = capacity;
    let mut unsat: Vec<usize> = (0..n).collect();
    // Sort unsatisfied by demand ascending (progressive filling).
    unsat.sort_by_key(|&i| demands[i]);
    while !unsat.is_empty() && remaining > 0 {
        let share = remaining / unsat.len() as u32;
        if share == 0 {
            // Give 1% each to the smallest demands until exhausted.
            for &i in unsat.iter() {
                if remaining == 0 {
                    break;
                }
                alloc[i] += 1;
                remaining -= 1;
            }
            break;
        }
        // Satisfy every demand below the share; they return leftovers.
        let (sat, rest): (Vec<usize>, Vec<usize>) = unsat
            .iter()
            .partition(|&&i| demands[i].saturating_sub(alloc[i]) <= share);
        if sat.is_empty() {
            // No demand fits fully: give the share to all and finish.
            for &i in &rest {
                alloc[i] += share;
            }
            break;
        }
        for &i in &sat {
            let need = demands[i] - alloc[i];
            alloc[i] += need;
            remaining -= need;
        }
        unsat = rest;
    }
    alloc
}

#[derive(Debug)]
pub struct MaxMin {
    pub shares: Vec<u32>,
}

impl MaxMin {
    pub fn from_entries(models: &[ModelEntry]) -> MaxMin {
        let demands: Vec<u32> = models.iter().map(|m| m.profile.knee_pct).collect();
        MaxMin { shares: max_min_allocation(&demands, 100) }
    }
}

impl Policy for MaxMin {
    fn name(&self) -> String {
        "max_min".into()
    }

    fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
        for (i, e) in v.models.iter().enumerate() {
            let share = self.shares[i];
            if share == 0 || v.gpu.n_running_of(i) > 0 {
                continue;
            }
            let queued = v.queue_len(i);
            if queued == 0 {
                continue;
            }
            let budget = e.profile.slo_ms;
            let b = choose_batch(
                BatchPolicy::Adaptive,
                &e.profile,
                &v.gpu.spec,
                queued,
                e.batch,
                share,
                Some(budget),
            );
            let b = if b == 0 { 1 } else { b };
            return vec![Launch { model: i, batch: b, pct: share, latency_ms_override: None }];
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_when_capacity_sufficient() {
        assert_eq!(max_min_allocation(&[20, 30, 40], 100), vec![20, 30, 40]);
    }

    #[test]
    fn smallest_demands_satisfied_first() {
        // Demands 20+30+40+50 = 140 > 100. Progressive filling: everyone
        // is capped at the highest fair share; small demands met fully.
        let a = max_min_allocation(&[20, 30, 40, 50], 100);
        assert_eq!(a[0], 20, "smallest demand fully satisfied: {a:?}");
        let total: u32 = a.iter().sum();
        assert!(total <= 100);
        // Larger demands get equal leftovers.
        assert_eq!(a[2], a[3], "unsatisfied demands share equally: {a:?}");
        assert!(a[2] < 40);
    }

    #[test]
    fn extreme_contention() {
        let a = max_min_allocation(&[60, 60, 60, 60], 100);
        let total: u32 = a.iter().sum();
        assert!(total <= 100);
        assert!(a.iter().all(|&x| x == 25), "{a:?}");
    }

    #[test]
    fn empty_and_zero() {
        assert!(max_min_allocation(&[], 100).is_empty());
        assert_eq!(max_min_allocation(&[10, 10], 0), vec![0, 0]);
    }

    #[test]
    fn favors_small_demand_models_in_runtime() {
        use crate::profile::by_name;
        use crate::sim::{entries_at_optimum, Sim, SimConfig};
        use crate::workload::{merged_stream, Arrivals};
        // Fig. 10b: Max-Min gives the low-demand Mobilenet more runtime
        // (relative to its knee needs) than heavy models get.
        let names = ["mobilenet", "resnet50", "vgg19", "alexnet"];
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> = profiles
            .iter()
            .map(|p| (Arrivals::Poisson { rate: 700.0 }, p.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, 5_000.0, 17);
        let mut pol = MaxMin::from_entries(&entries);
        let mut sim = Sim::new(SimConfig { horizon_ms: 5_000.0, ..Default::default() }, entries);
        let rep = sim.run(&mut pol, &reqs);
        // Mobilenet (demand 20, fully satisfied) meets nearly all SLOs.
        let mob = &rep.per_model[0];
        let ok = mob.served_in_slo as f64 / mob.offered().max(1) as f64;
        assert!(ok > 0.5, "mobilenet in-SLO {ok}");
    }
}
