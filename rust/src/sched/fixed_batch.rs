//! FB — Fixed batching on *default* (uncontrolled) CUDA MPS (§7).
//!
//! Every model always waits for its full max batch (16) and launches the
//! moment it has one, with no GPU% caps: all models run concurrently and
//! contend for SMs. Default MPS gives no compute isolation, so when `n`
//! models run concurrently each effectively receives ~100/n% of the SMs
//! *plus* an interference penalty (GSLICE measured slowdowns beyond fair
//! sharing from cache/scheduler contention under default MPS).

use crate::gpu::Us;
use crate::sim::{Launch, Policy, SimView};

#[derive(Debug)]
pub struct FixedBatch {
    /// Multiplicative latency penalty per *additional* concurrent model
    /// (default 15%/model, the uncontrolled-MPS interference).
    pub interference_per_peer: f64,
}

impl Default for FixedBatch {
    fn default() -> Self {
        FixedBatch { interference_per_peer: 0.15 }
    }
}

impl FixedBatch {
    pub fn new() -> FixedBatch {
        FixedBatch::default()
    }
}

impl Policy for FixedBatch {
    fn name(&self) -> String {
        "fixed_batch_mps".into()
    }

    fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
        // One launch per call; the engine re-invokes until quiescent.
        for (i, e) in v.models.iter().enumerate() {
            if v.gpu.n_running_of(i) > 0 {
                continue; // one in-flight batch per model process
            }
            let queued = v.queue_len(i) as u32;
            if queued < e.profile.max_batch {
                continue; // fixed batching: wait for a full batch
            }
            let b = e.profile.max_batch;
            // Effective share under default MPS with n concurrent models.
            let n_after = v.gpu.n_running() as u32 + 1;
            let share = (100 / n_after).max(1);
            let base = e.profile.latency_ms_on(&v.gpu.spec, share, b);
            let interference =
                1.0 + self.interference_per_peer * (n_after.saturating_sub(1)) as f64;
            // NOTE: the share is fixed at launch time — an approximation
            // of continuously varying contention (documented in DESIGN.md).
            return vec![Launch {
                model: i,
                batch: b,
                pct: share,
                latency_ms_override: Some(base * interference),
            }];
        }
        Vec::new()
    }

    fn next_wakeup(&mut self, _v: &SimView) -> Option<Us> {
        None // purely event-driven: arrivals/completions trigger dispatch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::sim::{entries_at_optimum, Sim, SimConfig};
    use crate::workload::{merged_stream, Arrivals};

    fn run(names: &[&str], rate: f64, horizon_ms: f64) -> crate::metrics::RunReport {
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> =
            profiles.iter().map(|p| (Arrivals::Poisson { rate }, p.slo_ms)).collect();
        let reqs = merged_stream(&specs, horizon_ms, 21);
        let mut pol = FixedBatch::new();
        let mut sim = Sim::new(
            SimConfig { horizon_ms, allow_oversub: true, ..Default::default() },
            entries,
        );
        sim.run(&mut pol, &reqs)
    }

    #[test]
    fn launches_only_full_batches() {
        let rep = run(&["alexnet", "mobilenet"], 400.0, 3_000.0);
        for m in &rep.per_model {
            assert!(m.batches > 0, "{} never ran", m.name);
            assert!(
                (m.mean_batch() - 16.0).abs() < 1e-9,
                "{}: mean batch {} ≠ 16",
                m.name,
                m.mean_batch()
            );
        }
    }

    #[test]
    fn low_rate_models_miss_slos_waiting_for_full_batch() {
        // At 100 req/s, assembling 16 takes ~160 ms ≫ the 25 ms SLO:
        // most requests are served far too late (only the last few of
        // each batch make their deadline) — the paper's FB pathology.
        let rep = run(&["alexnet"], 100.0, 4_000.0);
        let m = &rep.per_model[0];
        let viol_frac = m.slo_violations() as f64 / m.offered() as f64;
        assert!(viol_frac > 0.5, "violation fraction {viol_frac}");
        assert!(m.latency_summary().p50 > 25.0, "p50 {}", m.latency_summary().p50);
    }

    #[test]
    fn concurrency_inflates_latency() {
        // Same per-model rate; more models ⇒ smaller effective share +
        // interference ⇒ higher per-batch latency for model 0.
        let solo = run(&["resnet50"], 600.0, 3_000.0);
        let multi = run(&["resnet50", "vgg19", "alexnet", "mobilenet"], 600.0, 3_000.0);
        let s = solo.per_model[0].latency_summary().p50;
        let m = multi.per_model[0].latency_summary().p50;
        assert!(m > s, "p50 solo {s} vs multiplexed {m}");
    }
}
