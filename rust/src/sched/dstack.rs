//! D-STACK: dynamic, fair spatio-temporal scheduling (§6).
//!
//! Two cooperating mechanisms per *session* (period of the largest SLO):
//!
//! 1. **Static spatio-temporal plan** (§6.1.1). Each model gets
//!    `⌈session/SLO⌉` planned instances with per-instance release times
//!    `k·SLO` and deadlines `(k+1)·SLO` (spreading consecutive instances
//!    of short-SLO models as far apart as possible); instances are placed
//!    EDF-first onto a capacity-reservation timeline
//!    ([`super::CapTimeline`]), never oversubscribing 100% GPU and never
//!    preempting. If a model's knee doesn't fit by its deadline, reduced
//!    GPU% levels are tried (the paper: "D-STACK's scheduler can also
//!    schedule a model with GPU% lower than its Knee, albeit with high
//!    inference latency").
//!
//! 2. **Fair, opportunistic, dynamic pass** (§6.1.2). Triggered on every
//!    request arrival and batch completion. Models are offered idle
//!    capacity in scoreboard order (fewest runs in the last ten sessions
//!    first). A dynamic launch fires when a full optimal batch is queued
//!    or the oldest request is under deadline pressure, and commits only
//!    if the remaining plan can be *recomputed* to coexist with it (the
//!    paper's "dynamically recomputes the schedule") — so opportunism
//!    never endangers other models' planned instances.

use super::{session_len_us, CapTimeline, Scoreboard};
use crate::batching::{choose_batch, BatchPolicy};
use crate::gpu::{ms_to_us, GpuSim, Us};
use crate::sim::{Launch, ModelEntry, Policy, SimView};

/// One planned (not yet executed) instance.
#[derive(Debug, Clone)]
struct Planned {
    model: usize,
    start: Us,
    end: Us,
    pct: u32,
    release: Us,
    deadline: Us,
    /// Required instances realize the per-SLO-window guarantee; optional
    /// (half-offset) ones are best-effort and may be dropped on replan.
    required: bool,
}

/// D-STACK policy configuration.
#[derive(Debug, Clone)]
pub struct DstackCfg {
    /// Enable the opportunistic dynamic pass (disable to obtain the
    /// "plain spatio-temporal" schedule of Fig. 9b).
    pub opportunistic: bool,
    /// Scoreboard window in sessions (§6.1.2 uses ten).
    pub scoreboard_window: usize,
    /// GPU% levels (fractions of knee) tried when the knee doesn't fit.
    pub degrade_levels: Vec<f64>,
    /// Deadline-pressure factor: a dynamic launch fires when the oldest
    /// request's slack falls below `factor × inference latency + 2 ms`.
    /// 2.5 empirically minimizes SLO violations on the C-4 mix (see
    /// docs/EXPERIMENTS.md §Notes for the sweep).
    pub urgency_factor: f64,
}

impl Default for DstackCfg {
    fn default() -> Self {
        DstackCfg {
            opportunistic: true,
            scoreboard_window: 10,
            degrade_levels: vec![1.0, 0.75, 0.5],
            urgency_factor: 2.5,
        }
    }
}

#[derive(Debug)]
pub struct Dstack {
    cfg: DstackCfg,
    session_us: Us,
    session_start: Us,
    planned: Vec<Planned>,
    scoreboard: Scoreboard,
    initialized: bool,
    /// Statistics: dynamic launches committed (for tests/reports).
    pub dynamic_launches: u64,
    /// Statistics: planned launches executed.
    pub planned_launches: u64,
}

impl Dstack {
    pub fn from_entries(models: &[ModelEntry]) -> Dstack {
        Dstack::with_cfg(models, DstackCfg::default())
    }

    pub fn with_cfg(models: &[ModelEntry], cfg: DstackCfg) -> Dstack {
        let session_us = session_len_us(models);
        Dstack {
            scoreboard: Scoreboard::new(models.len(), cfg.scoreboard_window),
            cfg,
            session_us,
            session_start: 0,
            planned: Vec::new(),
            initialized: false,
            dynamic_launches: 0,
            planned_launches: 0,
        }
    }

    /// Base timeline: capacity held by batches already running on the GPU.
    fn running_timeline(now: Us, gpu: &GpuSim) -> CapTimeline {
        let mut tl = CapTimeline::new();
        for r in gpu.running() {
            if r.end > now {
                tl.add(now, r.end, r.pct);
            }
        }
        tl
    }

    /// EDF placement of `insts` (release/deadline/model triples) onto
    /// `timeline`. Returns the placements; instances that cannot fit even
    /// degraded are skipped (the dynamic pass may still serve them).
    fn place_instances(
        &self,
        insts: &mut [(usize, Us, Us)], // (model, release, deadline)
        models: &[ModelEntry],
        gpu_spec: &crate::profile::GpuSpec,
        timeline: &mut CapTimeline,
        not_before: Us,
        required: bool,
    ) -> Vec<Planned> {
        // EDF: earliest deadline first; longer runtime first on ties so
        // bulky instances grab contiguous capacity early. total_cmp
        // orders identically to partial_cmp on the non-NaN runtimes
        // profiles produce; a NaN runtime (greatest in the total order,
        // so first in this descending tiebreak) sorts deterministically
        // instead of panicking.
        insts.sort_by(|a, b| {
            a.2.cmp(&b.2).then_with(|| {
                let ra = models[a.0].profile.runtime_ms;
                let rb = models[b.0].profile.runtime_ms;
                rb.total_cmp(&ra)
            })
        });
        let mut placed = Vec::new();
        for &mut (model, release, deadline) in insts {
            let e = &models[model];
            let release = release.max(not_before);
            for level in &self.cfg.degrade_levels {
                let pct = ((e.pct as f64 * level).round() as u32).max(5);
                let dur = ms_to_us(e.profile.latency_ms_on(gpu_spec, pct, e.batch)).max(1);
                if deadline < dur || deadline - dur < release {
                    continue;
                }
                let latest_start = deadline - dur;
                if let Some(s) = timeline.earliest_fit(release, latest_start, dur, pct, 100) {
                    timeline.add(s, s + dur, pct);
                    placed.push(Planned {
                        model,
                        start: s,
                        end: s + dur,
                        pct,
                        release,
                        deadline,
                        required,
                    });
                    break;
                }
            }
        }
        placed.sort_by_key(|p| p.start);
        placed
    }

    /// Build the session's static EDF plan (§6.1.1). `active` masks out
    /// control-plane tombstones: retired models must not hold planned
    /// capacity reservations.
    fn build_plan(&mut self, t0: Us, models: &[ModelEntry], active: &[bool], gpu: &GpuSim) {
        self.session_start = t0;
        let mut timeline = Self::running_timeline(t0, gpu);
        // Required instances: one per SLO window per model (§6.1's hard
        // constraint: "the DNN model must be scheduled at least once
        // before an interval equal to its SLO").
        let mut required: Vec<(usize, Us, Us)> = Vec::new();
        // Optional instances: for models satisfying Eq. 12 (runtime ≤
        // SLO/2), an extra half-offset instance per window, so a request
        // arriving just after a launch still meets its deadline via the
        // next one (wait ≤ SLO/2, run ≤ SLO/2). Placed only in capacity
        // left over after all required instances fit.
        let mut optional: Vec<(usize, Us, Us)> = Vec::new();
        for (j, e) in models.iter().enumerate() {
            if !active.get(j).copied().unwrap_or(true) {
                continue;
            }
            let slo = ms_to_us(e.profile.slo_ms);
            let n = self.session_us.div_ceil(slo).max(1);
            for k in 0..n {
                required.push((j, t0 + k * slo, t0 + (k + 1) * slo));
            }
            let lat = e.profile.latency_ms_on(&gpu.spec, e.pct, e.batch);
            if lat <= e.profile.slo_ms / 2.0 {
                for k in 0..n {
                    let rel = t0 + k * slo + slo / 2;
                    let dl = (rel + slo).min(t0 + self.session_us + slo / 2);
                    optional.push((j, rel, dl));
                }
            }
        }
        self.planned =
            self.place_instances(&mut required, models, &gpu.spec, &mut timeline, t0, true);
        let extra =
            self.place_instances(&mut optional, models, &gpu.spec, &mut timeline, t0, false);
        self.planned.extend(extra);
        self.planned.sort_by_key(|p| p.start);
    }

    /// Re-place all pending planned instances around a tentative dynamic
    /// launch `(model, pct, [now, now+dur))`, excluding the launching
    /// model's next pending instance (the launch absorbs it). Returns the
    /// new plan if every other pending instance still fits.
    fn replan_with_launch(
        &self,
        v: &SimView,
        model: usize,
        pct: u32,
        dur: Us,
    ) -> Option<Vec<Planned>> {
        let mut timeline = Self::running_timeline(v.now, v.gpu);
        if timeline.peak(v.now, v.now + dur) + pct > 100 {
            return None;
        }
        timeline.add(v.now, v.now + dur, pct);
        // Pending instances, minus the launching model's next one (the
        // launch absorbs it). Required instances must all re-fit;
        // optional ones are re-placed best-effort.
        let mut absorbed = false;
        let mut req: Vec<(usize, Us, Us)> = Vec::new();
        let mut opt: Vec<(usize, Us, Us)> = Vec::new();
        for p in &self.planned {
            if p.model == model && !absorbed {
                absorbed = true;
                continue;
            }
            if p.required {
                req.push((p.model, p.release, p.deadline));
            } else {
                opt.push((p.model, p.release, p.deadline));
            }
        }
        let must_place = req.len();
        let mut placed =
            self.place_instances(&mut req, v.models, &v.gpu.spec, &mut timeline, v.now, true);
        if placed.len() != must_place {
            return None;
        }
        placed.extend(self.place_instances(
            &mut opt,
            v.models,
            &v.gpu.spec,
            &mut timeline,
            v.now,
            false,
        ));
        placed.sort_by_key(|p| p.start);
        Some(placed)
    }

    /// Pop planned instances due at `now`; returns launches. At most one
    /// launch per model per round: the view's queue lengths are a
    /// snapshot, so a second instance of the same model must wait for
    /// the next dispatch round (the engine re-calls until quiescent).
    fn due_planned(&mut self, v: &SimView) -> Vec<Launch> {
        let mut out: Vec<Launch> = Vec::new();
        let mut i = 0;
        while i < self.planned.len() {
            if self.planned[i].start > v.now
                || out.iter().any(|l| l.model == self.planned[i].model)
            {
                i += 1;
                continue;
            }
            let p = self.planned.remove(i);
            let queued = v.queue_len(p.model);
            if queued == 0 {
                continue; // capacity freed for the dynamic pass
            }
            if v.gpu.free_pct() < p.pct {
                // Carried-over occupancy squeezed this slot out; the
                // dynamic pass will reschedule the work.
                continue;
            }
            let e = &v.models[p.model];
            // Prefer a batch that finishes before the oldest request's
            // deadline; if none can, serve the largest batch anyway
            // (late service still beats dropping).
            let budget = v.deadline_budget_ms(p.model);
            let mut b = choose_batch(
                BatchPolicy::Optimal,
                &e.profile,
                &v.gpu.spec,
                queued,
                e.batch,
                p.pct,
                budget,
            );
            if b == 0 {
                b = choose_batch(
                    BatchPolicy::Optimal,
                    &e.profile,
                    &v.gpu.spec,
                    queued,
                    e.batch,
                    p.pct,
                    None,
                );
            }
            if b == 0 {
                continue;
            }
            self.scoreboard.record_run(p.model);
            self.planned_launches += 1;
            out.push(Launch { model: p.model, batch: b, pct: p.pct, latency_ms_override: None });
        }
        out
    }

    /// Opportunistic dynamic pass (§6.1.2).
    fn dynamic_pass(&mut self, v: &SimView) -> Vec<Launch> {
        if !self.cfg.opportunistic {
            return Vec::new();
        }
        // Candidate order: deadline-pressured models first (tightest
        // slack first — EDF spirit), then full-batch opportunities in
        // scoreboard-fairness order. (Small Vecs; measured: allocation
        // here is <5% of the event path — kept simple, see §Perf.)
        let mut urgent_models: Vec<(u64, usize)> = Vec::new();
        let mut full_models: Vec<usize> = Vec::new();
        for j in self.scoreboard.priority_order() {
            let e = &v.models[j];
            let queued = v.queue_len(j);
            if queued == 0 || v.gpu.n_running_of(j) > 0 {
                continue;
            }
            // Opportunistic ≠ eager: fire with a full optimal batch, or
            // under deadline pressure (§5: under-filled batches waste
            // GPU%·time).
            let full = queued >= e.batch as usize;
            let slack_ms = v.deadline_budget_ms(j).unwrap_or(f64::INFINITY);
            let need_ms =
                e.profile.latency_ms_on(&v.gpu.spec, e.pct, (queued as u32).min(e.batch));
            let urgent = slack_ms <= self.cfg.urgency_factor * need_ms + 2.0;
            if urgent {
                urgent_models.push((v.oldest_deadline(j).unwrap_or(u64::MAX), j));
            } else if full {
                full_models.push(j);
            }
        }
        urgent_models.sort();
        let order: Vec<usize> =
            urgent_models.into_iter().map(|(_, j)| j).chain(full_models).collect();
        for j in order {
            let e = &v.models[j];
            let queued = v.queue_len(j);
            for level in &self.cfg.degrade_levels {
                let pct = ((e.pct as f64 * level).round() as u32).max(5);
                if v.gpu.free_pct() < pct {
                    continue;
                }
                let b = choose_batch(
                    BatchPolicy::Optimal,
                    &e.profile,
                    &v.gpu.spec,
                    queued,
                    e.batch,
                    pct,
                    None,
                );
                if b == 0 {
                    continue;
                }
                let dur = ms_to_us(e.profile.latency_ms_on(&v.gpu.spec, pct, b)).max(1);
                // Fast path (§Perf): if the launch fits under current
                // usage plus a *sum* upper bound of overlapping planned
                // reservations, it cannot disturb any plan — commit
                // without replanning (the plan keeps its own future
                // instance; it simply finds an empty queue later).
                let end = v.now + dur;
                let overlap_sum: u32 = self
                    .planned
                    .iter()
                    .filter(|p| p.start < end && p.end > v.now)
                    .map(|p| p.pct)
                    .sum();
                if v.gpu.used_pct() + overlap_sum + pct <= 100 {
                    self.scoreboard.record_run(j);
                    self.dynamic_launches += 1;
                    return vec![Launch { model: j, batch: b, pct, latency_ms_override: None }];
                }
                // Slow path: commit only if the rest of the plan can be
                // recomputed around this launch (paper: "dynamically
                // recomputes the schedule").
                if let Some(new_plan) = self.replan_with_launch(v, j, pct, dur) {
                    self.planned = new_plan;
                    self.scoreboard.record_run(j);
                    self.dynamic_launches += 1;
                    return vec![Launch { model: j, batch: b, pct, latency_ms_override: None }];
                }
            }
        }
        Vec::new()
    }
}

impl Policy for Dstack {
    fn name(&self) -> String {
        if self.cfg.opportunistic {
            "dstack".into()
        } else {
            "spatio_temporal".into()
        }
    }

    fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
        // Session roll-over (and first-call initialization).
        if !self.initialized || v.now >= self.session_start + self.session_us {
            if self.initialized {
                self.scoreboard.end_session();
            }
            self.initialized = true;
            let t0 = (v.now / self.session_us) * self.session_us;
            let models = v.models.to_vec();
            let active = v.active.to_vec();
            self.build_plan(t0, &models, &active, v.gpu);
        }
        let mut launches = self.due_planned(v);
        if launches.is_empty() {
            launches = self.dynamic_pass(v);
        }
        launches
    }

    fn next_wakeup(&mut self, v: &SimView) -> Option<Us> {
        let next_plan = self.planned.iter().map(|p| p.start).filter(|&s| s > v.now).min();
        let next_session = self.session_start + self.session_us;
        Some(next_plan.unwrap_or(next_session).min(next_session))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::sim::{entries_at_optimum, Sim, SimConfig};
    use crate::workload::{merged_stream, slo_proportional_rates, Arrivals};

    fn entries(names: &[&str]) -> Vec<ModelEntry> {
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        entries_at_optimum(&profiles)
    }

    pub(super) fn run_policy(
        names: &[&str],
        total_rate: f64,
        horizon_ms: f64,
        opportunistic: bool,
        seed: u64,
    ) -> (crate::metrics::RunReport, Sim) {
        let es = entries(names);
        let slos: Vec<f64> = es.iter().map(|e| e.profile.slo_ms).collect();
        let rates = slo_proportional_rates(total_rate, &slos);
        let specs: Vec<_> = es
            .iter()
            .zip(&rates)
            .map(|(e, &r)| (Arrivals::Poisson { rate: r }, e.profile.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, horizon_ms, seed);
        let mut cfg = DstackCfg { opportunistic, ..Default::default() };
        if let Ok(f) = std::env::var("DSTACK_URGENCY") {
            cfg.urgency_factor = f.parse().unwrap();
        }
        let mut pol = Dstack::with_cfg(&es, cfg);
        let mut sim = Sim::new(SimConfig { horizon_ms, gantt: true, ..Default::default() }, es);
        let rep = sim.run(&mut pol, &reqs);
        (rep, sim)
    }

    #[test]
    fn plan_never_oversubscribes() {
        let es = entries(&["alexnet", "mobilenet", "resnet50", "vgg19"]);
        let gpu = GpuSim::new(crate::profile::V100.clone(), es.len(), false);
        let mut d = Dstack::from_entries(&es);
        d.build_plan(0, &es, &vec![true; es.len()], &gpu);
        assert!(!d.planned.is_empty());
        let mut tl = CapTimeline::new();
        for p in &d.planned {
            tl.add(p.start, p.end, p.pct);
        }
        assert!(tl.peak(0, d.session_us) <= 100);
    }

    #[test]
    fn every_model_planned_at_least_slo_count() {
        // §6.1: a model with SLO s must be planned ≥ ⌈session/s⌉ times
        // when feasible. For the 3-model mix of Fig. 9 all fit.
        let es = entries(&["alexnet", "resnet50", "vgg19"]);
        let gpu = GpuSim::new(crate::profile::V100.clone(), es.len(), false);
        let mut d = Dstack::from_entries(&es);
        d.build_plan(0, &es, &vec![true; es.len()], &gpu);
        let session = d.session_us;
        for (j, e) in es.iter().enumerate() {
            let want = session.div_ceil(ms_to_us(e.profile.slo_ms));
            let got = d.planned.iter().filter(|p| p.model == j).count() as u64;
            assert!(got >= want, "{}: planned {got} < required {want}", e.profile.name);
        }
    }

    #[test]
    fn edf_tiebreak_total_cmp() {
        // Equal deadlines tie-break on descending runtime — vgg19's
        // instance must sort ahead of alexnet's. Regression for the
        // NaN-unsafe partial_cmp().unwrap() this tiebreak used.
        let es = entries(&["alexnet", "vgg19"]);
        let d = Dstack::from_entries(&es);
        let mut tl = CapTimeline::new();
        let mut insts: Vec<(usize, Us, Us)> = vec![(0, 0, 80_000), (1, 0, 80_000)];
        let placed =
            d.place_instances(&mut insts, &es, &crate::profile::V100, &mut tl, 0, true);
        assert_eq!(insts[0].0, 1, "longer runtime first on deadline ties");
        assert!(!placed.is_empty());
        // A NaN runtime key orders deterministically (greatest in the
        // total order, so first in this descending tiebreak) instead of
        // panicking mid-plan.
        let mut keys = vec![0.5f64, f64::NAN, 2.0];
        keys.sort_by(|a, b| b.total_cmp(a));
        assert!(keys[0].is_nan());
        assert_eq!(&keys[1..], &[2.0, 0.5]);
    }

    #[test]
    fn short_slo_instances_are_spread() {
        let es = entries(&["alexnet", "resnet50", "vgg19"]);
        let gpu = GpuSim::new(crate::profile::V100.clone(), es.len(), false);
        let mut d = Dstack::from_entries(&es);
        d.build_plan(0, &es, &vec![true; es.len()], &gpu);
        // Alexnet (SLO 25 ms in a 100 ms session) runs 4 *required*
        // instances, one per 25 ms window (max spreading = release at
        // k·SLO). Optional half-offset instances may add more.
        let mut starts: Vec<Us> = d
            .planned
            .iter()
            .filter(|p| p.model == 0 && p.required)
            .map(|p| p.start)
            .collect();
        starts.sort();
        assert_eq!(starts.len(), 4);
        for (k, s) in starts.iter().enumerate() {
            let lo = k as Us * 25_000;
            let hi = (k as Us + 1) * 25_000;
            assert!(*s >= lo && *s < hi, "instance {k} at {s} outside its window");
        }
    }

    #[test]
    fn meets_slos_for_c4_mix() {
        // §7: "there are no SLO violations in D-STACK when multiplexing
        // 2-4 models". Allow a small epsilon for boundary effects.
        let (rep, _) =
            run_policy(&["mobilenet", "alexnet", "resnet50", "vgg19"], 1_000.0, 10_000.0, true, 1);
        let viol = rep.violation_fraction();
        assert!(viol < 0.05, "violation fraction {viol}");
        for m in &rep.per_model {
            assert!(m.served > 0, "{} starved", m.name);
        }
    }

    #[test]
    fn opportunistic_pass_raises_utilization() {
        // Fig. 9b vs 9c: dynamic pass lifts utilization (60% → 74%).
        let (plain, _) = run_policy(&["alexnet", "resnet50", "vgg19"], 1_400.0, 8_000.0, false, 3);
        let (dynamic, _) = run_policy(&["alexnet", "resnet50", "vgg19"], 1_400.0, 8_000.0, true, 3);
        let u_plain = plain.mean_utilization();
        let u_dyn = dynamic.mean_utilization();
        assert!(u_dyn > u_plain, "dynamic {u_dyn} should exceed plain {u_plain}");
        assert!(dynamic.total_throughput() >= plain.total_throughput());
    }

    #[test]
    fn beats_temporal_sharing_on_throughput() {
        // Headline claim: ≥2× throughput vs temporal sharing for the
        // 4-model mix (paper reports up to 4×).
        use crate::sched::temporal::Temporal;
        let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
        let es = entries(&names);
        let slos: Vec<f64> = es.iter().map(|e| e.profile.slo_ms).collect();
        let rates = slo_proportional_rates(1_900.0, &slos);
        let specs: Vec<_> = es
            .iter()
            .zip(&rates)
            .map(|(e, &r)| (Arrivals::Poisson { rate: r }, e.profile.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, 10_000.0, 5);

        let mut tpol = Temporal::from_entries(&es);
        let mut tsim =
            Sim::new(SimConfig { horizon_ms: 10_000.0, ..Default::default() }, es.clone());
        let trep = tsim.run(&mut tpol, &reqs);

        let mut dpol = Dstack::from_entries(&es);
        let mut dsim = Sim::new(SimConfig { horizon_ms: 10_000.0, ..Default::default() }, es);
        let drep = dsim.run(&mut dpol, &reqs);

        let t = trep.total_throughput();
        let d = drep.total_throughput();
        assert!(d > 1.5 * t, "dstack {d} vs temporal {t}");
    }

    #[test]
    fn scoreboard_fairness_gives_similar_runtimes() {
        // Fig. 10b: "With D-STACK, all the models get similar GPU time".
        let (rep, _) =
            run_policy(&["mobilenet", "alexnet", "resnet50", "vgg19"], 1_900.0, 10_000.0, true, 7);
        let fairness = rep.runtime_fairness();
        assert!(fairness > 0.5, "Jain fairness {fairness}");
    }

    #[test]
    fn uses_both_planned_and_dynamic_launches() {
        let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
        let es = entries(&names);
        let slos: Vec<f64> = es.iter().map(|e| e.profile.slo_ms).collect();
        let rates = slo_proportional_rates(1_500.0, &slos);
        let specs: Vec<_> = es
            .iter()
            .zip(&rates)
            .map(|(e, &r)| (Arrivals::Poisson { rate: r }, e.profile.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, 5_000.0, 2);
        let mut pol = Dstack::from_entries(&es);
        let mut sim = Sim::new(SimConfig { horizon_ms: 5_000.0, ..Default::default() }, es);
        sim.run(&mut pol, &reqs);
        assert!(pol.planned_launches > 0, "static plan never fired");
        assert!(pol.dynamic_launches > 0, "dynamic pass never fired");
    }
}

#[cfg(test)]
mod debug_tests {
    #[test]
    #[ignore]
    fn debug_c4() {
        let rate: f64 = std::env::var("DSTACK_RATE").ok().and_then(|v| v.parse().ok()).unwrap_or(1_000.0);
        let (rep, _) = super::tests::run_policy(
            &["mobilenet", "alexnet", "resnet50", "vgg19"],
            rate,
            10_000.0,
            true,
            1,
        );
        for m in &rep.per_model {
            eprintln!(
                "{}: served={} in_slo={} dropped={} batches={} meanb={:.1} p99={:.1}",
                m.name,
                m.served,
                m.served_in_slo,
                m.dropped,
                m.batches,
                m.mean_batch(),
                m.latency_summary().p99
            );
        }
        eprintln!("util={:.2} viol={:.3}", rep.mean_utilization(), rep.violation_fraction());
    }
}
