//! Max-throughput schedule (§6.3, Fig. 10): a spatial packing that
//! greedily maximizes aggregate images/s with no fairness constraint.
//! Models are ranked by throughput density — images/s per GPU% at their
//! knee — and the densest queued model launches whenever capacity
//! allows. Light models dominate; heavy models run only in leftovers.

use crate::batching::{choose_batch, BatchPolicy};
use crate::sim::{Launch, ModelEntry, Policy, SimView};

#[derive(Debug)]
pub struct MaxThroughput {
    /// Model indices sorted by descending throughput density.
    order: Vec<usize>,
}

impl MaxThroughput {
    pub fn from_entries(models: &[ModelEntry]) -> MaxThroughput {
        let mut order: Vec<usize> = (0..models.len()).collect();
        let density = |e: &ModelEntry| {
            let thpt = e.profile.throughput(e.pct, e.batch); // img/s
            thpt / e.pct as f64
        };
        // total_cmp: identical order to partial_cmp on non-NaN
        // densities; a degenerate profile (0/0 → NaN, greatest in the
        // total order, so first in this descending sort) orders
        // deterministically instead of panicking the scheduler.
        order.sort_by(|&a, &b| density(&models[b]).total_cmp(&density(&models[a])));
        MaxThroughput { order }
    }
}

impl Policy for MaxThroughput {
    fn name(&self) -> String {
        "max_throughput".into()
    }

    fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
        for &i in &self.order {
            let e = &v.models[i];
            if v.gpu.n_running_of(i) > 0 {
                continue;
            }
            let queued = v.queue_len(i);
            if queued == 0 || v.gpu.free_pct() < e.pct {
                continue;
            }
            let b = choose_batch(
                BatchPolicy::Optimal,
                &e.profile,
                &v.gpu.spec,
                queued,
                e.batch,
                e.pct,
                None,
            );
            if b == 0 {
                continue;
            }
            return vec![Launch { model: i, batch: b, pct: e.pct, latency_ms_override: None }];
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::sim::{entries_at_optimum, Sim, SimConfig};
    use crate::workload::{merged_stream, Arrivals};

    #[test]
    fn ranks_light_models_first() {
        let profiles: Vec<_> =
            ["vgg19", "alexnet"].iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let mt = MaxThroughput::from_entries(&entries);
        // Alexnet (index 1) has far higher images/s per GPU%.
        assert_eq!(mt.order[0], 1);
    }

    #[test]
    fn favors_light_models_under_contention() {
        let names = ["alexnet", "mobilenet", "resnet50", "vgg19"];
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> = profiles
            .iter()
            .map(|p| (Arrivals::Poisson { rate: 900.0 }, p.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, 5_000.0, 99);
        let mut pol = MaxThroughput::from_entries(&entries);
        let mut sim = Sim::new(SimConfig { horizon_ms: 5_000.0, ..Default::default() }, entries);
        let rep = sim.run(&mut pol, &reqs);
        // Light models should be served at a much higher rate than VGG.
        let alex = rep.per_model[0].served;
        let vgg = rep.per_model[3].served;
        assert!(alex > 2 * vgg, "alexnet {alex} vs vgg {vgg}");
        // And aggregate throughput is high.
        assert!(rep.total_throughput() > 1_000.0, "{}", rep.total_throughput());
    }

    #[test]
    fn density_order_total_cmp() {
        // Regression for the NaN-unsafe partial_cmp().unwrap() this sort
        // used: on the finite densities real entries produce the order
        // must be descending (same as partial_cmp gave), and a NaN key
        // must order deterministically instead of panicking.
        let names = ["alexnet", "mobilenet", "resnet50", "vgg19"];
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let pol = MaxThroughput::from_entries(&entries);
        let density = |e: &ModelEntry| e.profile.throughput(e.pct, e.batch) / e.pct as f64;
        for w in pol.order.windows(2) {
            assert!(
                density(&entries[w[0]]) >= density(&entries[w[1]]),
                "order not descending by density"
            );
        }
        let mut keys = vec![1.0f64, f64::NAN, 3.0, 2.0];
        keys.sort_by(|a, b| b.total_cmp(a));
        assert!(keys[0].is_nan(), "NaN is greatest in the total order");
        assert_eq!(&keys[1..], &[3.0, 2.0, 1.0]);
    }
}
