//! PJRT runtime: loads the AOT artifacts produced by `python/compile/
//! aot.py` (HLO *text* — see /opt/xla-example/README.md for why not
//! serialized protos) and executes them on the PJRT CPU client from the
//! L3 hot path. Python never runs at serving time: model weights are
//! regenerated in-process with the same splitmix64 scheme the compile
//! path used, and validated against the manifest's self-check outputs.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One weight tensor's recipe (mirrors `Spec.params` in model.py).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub seed: u64,
    pub scale: f64,
}

/// Expected output for the deterministic iota input (cross-language
/// correctness contract).
#[derive(Debug, Clone)]
pub struct SelfCheck {
    pub output_sum: f64,
    pub output_first8: Vec<f64>,
}

/// Manifest entry for one (model, batch) artifact.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub model: String,
    pub batch: u32,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub selfcheck: SelfCheck,
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut artifacts = Vec::new();
        for a in j.req("artifacts").map_err(|e| anyhow!(e))?.as_arr().unwrap_or(&[]) {
            let shapes = |key: &str| -> Result<Vec<usize>> {
                Ok(a.req(key)
                    .map_err(|e| anyhow!(e))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{key} not an array"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect())
            };
            let mut params = Vec::new();
            for p in a.req("params").map_err(|e| anyhow!(e))?.as_arr().unwrap_or(&[]) {
                params.push(ParamSpec {
                    name: p.req_str("name").map_err(|e| anyhow!(e))?.to_string(),
                    shape: p
                        .req("shape")
                        .map_err(|e| anyhow!(e))?
                        .as_arr()
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    seed: p.req_u64("seed").map_err(|e| anyhow!(e))?,
                    scale: p.req_f64("scale").map_err(|e| anyhow!(e))?,
                });
            }
            let sc = a.req("selfcheck").map_err(|e| anyhow!(e))?;
            artifacts.push(Artifact {
                model: a.req_str("model").map_err(|e| anyhow!(e))?.to_string(),
                batch: a.req_u64("batch").map_err(|e| anyhow!(e))? as u32,
                file: a.req_str("file").map_err(|e| anyhow!(e))?.to_string(),
                input_shape: shapes("input_shape")?,
                output_shape: shapes("output_shape")?,
                params,
                selfcheck: SelfCheck {
                    output_sum: sc.req_f64("output_sum").map_err(|e| anyhow!(e))?,
                    output_first8: sc
                        .req("output_first8")
                        .map_err(|e| anyhow!(e))?
                        .as_arr()
                        .ok_or_else(|| anyhow!("output_first8"))?
                        .iter()
                        .filter_map(Json::as_f64)
                        .collect(),
                },
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, model: &str, batch: u32) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.model == model && a.batch == batch)
    }

    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.artifacts.iter().map(|a| a.model.clone()).collect();
        names.dedup();
        names
    }

    /// Batch sizes available for a model, ascending.
    pub fn batches(&self, model: &str) -> Vec<u32> {
        let mut bs: Vec<u32> =
            self.artifacts.iter().filter(|a| a.model == model).map(|a| a.batch).collect();
        bs.sort_unstable();
        bs
    }
}

// ---------------------------------------------------------------------------
// Deterministic weights (bit-identical to python's model.det_weights).
// ---------------------------------------------------------------------------

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Regenerate a weight tensor (row-major) exactly as the compile path
/// did: element i of parameter `seed` is splitmix64(seed·2³² + i) mapped
/// to [-scale, scale] via its top 53 bits.
pub fn det_weights(shape: &[usize], seed: u64, scale: f64) -> Vec<f32> {
    let n: usize = shape.iter().product();
    let base = seed << 32;
    (0..n as u64)
        .map(|i| {
            let z = splitmix64(base.wrapping_add(i));
            let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            ((2.0 * u - 1.0) * scale) as f32
        })
        .collect()
}

/// The deterministic self-check input (normalized iota — matches
/// `model.deterministic_input`).
pub fn iota_input(shape: &[usize]) -> Vec<f32> {
    let n: usize = shape.iter().product();
    (0..n).map(|i| i as f32 / n as f32 - 0.5).collect()
}

// ---------------------------------------------------------------------------
// Executable cache + execution.
// ---------------------------------------------------------------------------

/// A compiled (model, batch) executable with its resident weights.
pub struct Loaded {
    pub artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::Literal>,
}

impl Loaded {
    /// Run one batch. `input` must have `batch × item_len` elements.
    pub fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
        let want: usize = self.artifact.input_shape.iter().product();
        if input.len() != want {
            bail!("input length {} != expected {want}", input.len());
        }
        let dims: Vec<i64> = self.artifact.input_shape.iter().map(|&d| d as i64).collect();
        let x = xla::Literal::vec1(input).reshape(&dims)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + self.weights.len());
        args.push(&x);
        args.extend(self.weights.iter());
        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Items per input batch.
    pub fn batch(&self) -> u32 {
        self.artifact.batch
    }

    /// Run the manifest self-check: the iota input must reproduce the
    /// logits JAX computed at build time.
    pub fn selfcheck(&self) -> Result<()> {
        let out = self.infer(&iota_input(&self.artifact.input_shape))?;
        let sum: f64 = out.iter().map(|&v| v as f64).sum();
        let want = &self.artifact.selfcheck;
        if (sum - want.output_sum).abs() > 1e-3 * (1.0 + want.output_sum.abs()) {
            bail!("selfcheck sum mismatch: got {sum}, want {}", want.output_sum);
        }
        for (i, (&got, &w)) in out.iter().zip(want.output_first8.iter()).enumerate() {
            if (got as f64 - w).abs() > 1e-3 * (1.0 + w.abs()) {
                bail!("selfcheck[{i}]: got {got}, want {w}");
            }
        }
        Ok(())
    }
}

/// PJRT runtime: compile-once cache of (model, batch) executables.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: BTreeMap<(String, u32), Loaded>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: BTreeMap::new() })
    }

    /// Compile (or fetch cached) the executable for (model, batch) and
    /// materialize its weights.
    pub fn load(&mut self, model: &str, batch: u32) -> Result<&Loaded> {
        let key = (model.to_string(), batch);
        if !self.cache.contains_key(&key) {
            let artifact = self
                .manifest
                .find(model, batch)
                .ok_or_else(|| anyhow!("no artifact for {model} b{batch}"))?
                .clone();
            let path = self.manifest.dir.join(&artifact.file);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let mut weights = Vec::with_capacity(artifact.params.len());
            for p in &artifact.params {
                let vals = det_weights(&p.shape, p.seed, p.scale);
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                weights.push(xla::Literal::vec1(&vals).reshape(&dims)?);
            }
            self.cache.insert(key.clone(), Loaded { artifact, exe, weights });
        }
        Ok(self.cache.get(&key).unwrap())
    }

    /// Fetch an already-loaded executable.
    pub fn get(&self, model: &str, batch: u32) -> Option<&Loaded> {
        self.cache.get(&(model.to_string(), batch))
    }

    /// Load + self-check every artifact (startup validation).
    pub fn load_all_checked(&mut self) -> Result<usize> {
        let entries: Vec<(String, u32)> =
            self.manifest.artifacts.iter().map(|a| (a.model.clone(), a.batch)).collect();
        for (m, b) in &entries {
            self.load(m, *b)?.selfcheck().with_context(|| format!("{m} b{b}"))?;
        }
        Ok(entries.len())
    }
}

/// Default artifacts directory: `$DSTACK_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DSTACK_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_pins() {
        // Sanity: distinct, deterministic, full-range.
        assert_eq!(splitmix64(0), splitmix64(0));
        assert_ne!(splitmix64(0), splitmix64(1));
    }

    #[test]
    fn det_weights_distribution_and_contract() {
        let w = det_weights(&[10_000], 7, 1.0);
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!(w.iter().all(|v| (-1.0..=1.0).contains(v)));
        // Scale linearity (same contract as python test).
        let a = det_weights(&[4], 0, 1.0);
        let b = det_weights(&[4], 0, 0.5);
        for (x, y) in a.iter().zip(&b) {
            assert!((x * 0.5 - y).abs() < 1e-7);
        }
        // Seed decorrelation.
        assert_ne!(det_weights(&[4], 0, 1.0), det_weights(&[4], 1, 1.0));
    }

    #[test]
    fn iota_matches_python_contract() {
        // python: deterministic_input((2,2)) == [[-0.5,-0.25],[0,0.25]]
        assert_eq!(iota_input(&[2, 2]), vec![-0.5, -0.25, 0.0, 0.25]);
    }

    #[test]
    fn manifest_parse_rejects_missing() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }
}
