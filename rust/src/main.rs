//! `dstack` — the leader binary: serve real models (PJRT), run
//! virtual-time scheduling experiments, and regenerate every table and
//! figure of the paper.
//!
//! Subcommands:
//!   figures  --fig <2|3|4|...|19|all> [--out results]
//!            (--fig 17 also writes fig17_trace.json +
//!            fig17_timeseries.json, the observability artifacts;
//!            --fig 18 is the engine-failure resilience timeline:
//!            goodput + per-class p99 through a degrade→down→up
//!            cycle, hedged front door vs naive; --fig 19 is the
//!            flash-crowd overload timeline: goodput + p99 under
//!            brownout variant fallback vs shed-only vs retry-only)
//!   tables   --table <1|2|3|6|all>    [--out results]
//!   simulate --config <scenario.json> [--threads N|auto]
//!            [--exec-mode sparse|epoch] [--verbose]   (scenarios
//!            with a "cluster" block run on the placement/routing
//!            cluster engine; adding an "adaptive" block runs the
//!            adaptive control plane; a "lifecycle" block runs the
//!            long-tail memory manager; a "unified" block runs the
//!            merged cold-start-aware control plane; a "workload"
//!            block with a "trace" entry replays a recorded request
//!            log through the streaming cluster core; a "faults"
//!            block injects a deterministic engine-failure timeline
//!            and arms the resilient front door — SLO classes,
//!            deadline admission, hedged re-dispatch — on any of
//!            those paths, see configs/cluster_engine_failure.json;
//!            an "overload" block arms retry-with-backoff, per-engine
//!            circuit breakers and brownout variant fallback — models
//!            may declare degraded "variants" served when the primary
//!            cannot meet its deadline, see
//!            configs/cluster_brownout_flash.json)
//!   cluster  [--gpus V100,T4,...] [--placement ffd|lb]
//!            [--routing rr|jsq|p2c] [--sched dstack|temporal|triton|gslice]
//!            [--horizon ms] [--seed N] [--threads N|auto]
//!            [--workload poisson|mmpp|diurnal|flash]
//!            [--trace <log.csv|log.jsonl> [--on-unsorted reject|sort]]
//!            — Fig. 12 model mix on an arbitrary cluster; arrivals
//!            stream lazily from a synthetic generator or a recorded
//!            request log (timestamp_ms, model, count columns)
//!   adaptive [--config <scenario.json>] [--horizon ms] [--seed N]
//!            [--interval ms] [--alpha X] [--threshold X] [--rearm X]
//!            [--cooldown N] [--migration-cost ms] [--threads N|auto]
//!            — adaptive control plane vs static placement on the
//!            drifting-rate workload
//!   lifecycle [--config <scenario.json>] [--horizon ms] [--seed N]
//!            [--eviction lru|lfu|cost] [--mem-budget MiB]
//!            [--oblivious] [--threads N|auto]   — long-tail Zipf fleet
//!            under the memory manager; without --config, runs the
//!            canonical 24-model scenario and compares warmness-aware
//!            vs warm-oblivious routing
//!   unified  [--config <scenario.json>] [--horizon ms] [--seed N]
//!            [--gpus N] [--eviction lru|lfu|cost] [--mem-budget MiB]
//!            [--pressure-threshold N] [--no-drift] [--threads N|auto]
//!            — drift + memory-pressure stress under the merged
//!            cold-start-aware control plane; without --config, runs
//!            the canonical 24-model rotating-Zipf scenario on N V100s
//!            (default 4, sweepable to 64+) and compares the unified
//!            driver against the naive lifecycle-only composition
//!   optimize --model <name> [--slo ms]
//!   profile  --model <name> [--batch N]
//!   serve    [--seconds N] [--rate-scale X] [--policy dstack|fifo]
//!   selfcheck
//!
//! All cluster paths accept `--threads N|auto` (the engine-stepping
//! thread budget: `auto` = one per core, `1` = serial),
//! `--exec-mode sparse|epoch` (barrier discipline of the execution
//! core; sparse is the default) and `--verbose` (print execution-core
//! telemetry: barriers run/elided, batched arrivals, max lookahead —
//! plus the observability digest when a recorder ran).
//! Neither threads nor exec-mode ever changes results — reports are
//! byte-identical for any combination.
//!
//! Observability (see docs/OBSERVABILITY.md): `--emit-trace <file>`
//! writes a Perfetto-JSON event trace of the run, `--emit-timeseries
//! <file>` writes windowed time-series metrics; either flag forces the
//! matching recorder on (a scenario's `"observability"` block enables
//! them declaratively). Traces are byte-identical across exec modes
//! and thread counts, and recording never changes report bytes.

use dstack::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("figures") => figures(&args, args.get_or("fig", "all")),
        Some("tables") => {
            let t = args.get_or("table", "all");
            let key = if t == "all" { "tables".to_string() } else { format!("table{t}") };
            figures(&args, &key)
        }
        Some("simulate") => simulate(&args),
        Some("cluster") => cluster_cmd(&args),
        Some("adaptive") => adaptive_cmd(&args),
        Some("lifecycle") => lifecycle_cmd(&args),
        Some("unified") => unified_cmd(&args),
        Some("optimize") => optimize(&args),
        Some("profile") => profile_cmd(&args),
        Some("serve") => serve(&args),
        Some("selfcheck") => selfcheck(),
        _ => {
            eprintln!(
                "usage: dstack <figures|tables|simulate|cluster|adaptive|lifecycle|unified|optimize|profile|serve|selfcheck> [opts]"
            );
            std::process::exit(2);
        }
    }
}

fn figures(args: &Args, which: &str) -> anyhow::Result<()> {
    let out_dir = Path::new(args.get_or("out", "results")).to_path_buf();
    if matches!(which, "17" | "obs" | "timeline") {
        // The dedicated fig17 path also writes the run's observability
        // artifacts (one simulation serves all three outputs).
        let (data, trace, series) = dstack::figures::fig17_with_artifacts();
        println!("{}\n", data.render());
        data.write_csv(&out_dir)?;
        dstack::util::write_file(&out_dir.join("fig17_trace.json"), &trace)?;
        dstack::util::write_file(&out_dir.join("fig17_timeseries.json"), &series)?;
        println!("(CSV + trace + timeseries written to {})", out_dir.display());
        return Ok(());
    }
    for data in dstack::figures::generate(which) {
        println!("{}\n", data.render());
        data.write_csv(&out_dir)?;
    }
    if which == "9" || which == "all" {
        let gantt = dstack::figures::fig9_gantt_text();
        println!("{gantt}");
        dstack::util::write_file(&out_dir.join("fig9_gantt.txt"), &gantt)?;
    }
    if which == "all" {
        let d = dstack::figures::ablation();
        println!("{}\n", d.render());
        d.write_csv(&out_dir)?;
    }
    println!("(CSV written to {})", out_dir.display());
    Ok(())
}

/// `--threads N|auto` + `--exec-mode sparse|epoch` → execution-core
/// options, overriding `base` (a scenario's `parallelism`/`exec_mode`
/// fields or the defaults) where given. `--emit-trace`/
/// `--emit-timeseries` force the matching recorder on; neither ever
/// changes report bytes.
fn exec_opts_from_args(
    args: &Args,
    base: dstack::cluster::ExecOpts,
) -> anyhow::Result<dstack::cluster::ExecOpts> {
    let threads = match args.get("threads") {
        Some(s) => dstack::cluster::Parallelism::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?,
        None => base.threads,
    };
    let mode = match args.get("exec-mode") {
        Some(s) => dstack::cluster::ExecMode::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?,
        None => base.mode,
    };
    let mut obs = base.obs;
    if args.get("emit-trace").is_some() {
        obs.trace = true;
    }
    if args.get("emit-timeseries").is_some() {
        obs.timeseries = true;
    }
    Ok(dstack::cluster::ExecOpts { threads, mode, obs })
}

/// Overlay the exec flags onto a loaded scenario's own knobs.
fn overlay_exec_args(args: &Args, sc: &mut dstack::config::Scenario) -> anyhow::Result<()> {
    let opts = exec_opts_from_args(
        args,
        dstack::cluster::ExecOpts { threads: sc.parallelism, mode: sc.exec_mode, obs: sc.obs },
    )?;
    sc.parallelism = opts.threads;
    sc.exec_mode = opts.mode;
    sc.obs = opts.obs;
    Ok(())
}

/// `--verbose`: print the execution core's out-of-band telemetry
/// (never part of the report JSON — see `cluster::exec::ExecStats`)
/// plus a one-line typed-reject digest so failure modes are
/// diagnosable without parsing the report JSON.
fn print_exec_stats(args: &Args, rep: &dstack::cluster::ClusterReport) {
    if !args.has_flag("verbose") {
        return;
    }
    print_reject_digest(rep);
    if let Some(x) = &rep.exec {
        println!("{}", x.render());
    }
    if let Some(o) = &rep.obs {
        println!("{}", o.render());
    }
}

/// The full typed-reject taxonomy on one line: every terminal reject
/// class the front door can produce (per-SLO-class deadline,
/// unroutable, retry-exhausted, breaker-open) next to the untyped
/// remainder and the placement-time shed rate.
fn print_reject_digest(rep: &dstack::cluster::ClusterReport) {
    let rejected: u64 = rep.rejected.iter().sum();
    let shed: f64 = rep.shed_rps.iter().sum();
    let (dc, db, un) = rep
        .resilience
        .as_ref()
        .map(|r| (r.deadline_rejects_critical, r.deadline_rejects_bulk, r.unroutable_rejects))
        .unwrap_or((0, 0, 0));
    let (rc, rb, bo) = rep
        .overload
        .as_ref()
        .map(|o| (o.retry_exhausted_critical, o.retry_exhausted_bulk, o.breaker_open_rejects))
        .unwrap_or((0, 0, 0));
    let typed = dc + db + un + rc + rb;
    println!(
        "reject taxonomy: {rejected} rejected | deadline {dc} critical + {db} bulk, \
         unroutable {un}, retry-exhausted {rc} critical + {rb} bulk, \
         breaker-open {bo} (absorbed by retries/fallback), \
         untyped {}; placement shed {shed:.0} req/s",
        rejected.saturating_sub(typed),
    );
}

/// Write the run's observability artifacts where `--emit-trace` /
/// `--emit-timeseries` point. The report JSON never carries them —
/// these files are the only way the recorder's output leaves the
/// process (besides the `--verbose` digest).
fn emit_obs_artifacts(args: &Args, rep: &dstack::cluster::ClusterReport) -> anyhow::Result<()> {
    let Some(obs) = &rep.obs else { return Ok(()) };
    if let Some(path) = args.get("emit-trace") {
        dstack::util::write_file(Path::new(path), &obs.to_perfetto())?;
        println!("(trace written to {path})");
    }
    if let Some(path) = args.get("emit-timeseries") {
        dstack::util::write_file(Path::new(path), &obs.timeseries_json().to_string_pretty())?;
        println!("(timeseries written to {path})");
    }
    Ok(())
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or(args.get("config"))
        .ok_or_else(|| anyhow::anyhow!("simulate needs a scenario file"))?;
    let mut sc = dstack::config::Scenario::from_file(Path::new(path))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    overlay_exec_args(args, &mut sc)?;
    if sc.cluster.is_some() {
        if sc.unified.is_some() {
            let rep = dstack::config::run_unified_scenario(&sc);
            let names = lifecycle_fleet_names(&sc);
            println!("scenario '{}' unified policy={}", sc.name, rep.policy);
            print_cluster_report(&names, &rep);
            print_exec_stats(args, &rep);
            emit_obs_artifacts(args, &rep)?;
            return Ok(());
        }
        if sc.lifecycle.is_some() {
            let rep = dstack::config::run_lifecycle_scenario(&sc);
            let names = lifecycle_fleet_names(&sc);
            println!("scenario '{}' lifecycle policy={}", sc.name, rep.policy);
            print_cluster_report(&names, &rep);
            print_exec_stats(args, &rep);
            emit_obs_artifacts(args, &rep)?;
            return Ok(());
        }
        // Brownout variants appear in the report as extra models —
        // name the rows from the expanded list when one exists.
        let names: Vec<String> = match sc.overload_expanded() {
            Ok(Some((profiles, _))) => profiles.iter().map(|p| p.name.clone()).collect(),
            _ => sc.profiles().iter().map(|p| p.name.clone()).collect(),
        };
        let rep = if sc.workload.is_some() {
            // Trace replay: file errors surface as CLI errors, not panics.
            dstack::config::run_trace_scenario(&sc).map_err(|e| anyhow::anyhow!("{e}"))?
        } else if sc.adaptive.is_some() {
            dstack::config::run_adaptive_scenario(&sc)
        } else {
            dstack::config::run_cluster_scenario(&sc)
        };
        println!("scenario '{}' cluster policy={}", sc.name, rep.policy);
        print_cluster_report(&names, &rep);
        print_exec_stats(args, &rep);
        emit_obs_artifacts(args, &rep)?;
        return Ok(());
    }
    let rep = dstack::config::run_scenario(&sc);
    println!("scenario '{}' policy={} horizon={}s", sc.name, rep.policy, rep.horizon_s());
    let mut rows = Vec::new();
    for (i, m) in rep.per_model.iter().enumerate() {
        let s = m.latency_summary();
        rows.push(vec![
            m.name.clone(),
            m.served.to_string(),
            m.slo_violations().to_string(),
            format!("{:.1}", rep.throughput()[i]),
            format!("{:.1}", s.p50),
            format!("{:.1}", s.p99),
            format!("{:.1}", m.mean_batch()),
        ]);
    }
    println!(
        "{}",
        dstack::util::ascii_table(
            &["model", "served", "viol", "req/s", "p50_ms", "p99_ms", "mean_batch"],
            &rows
        )
    );
    println!(
        "total {:.0} req/s, utilization {:.1}%, violation fraction {:.3}",
        rep.total_throughput(),
        rep.mean_utilization() * 100.0,
        rep.violation_fraction()
    );
    Ok(())
}

fn print_cluster_report(names: &[String], rep: &dstack::cluster::ClusterReport) {
    let mut rows = Vec::new();
    for (m, name) in names.iter().enumerate() {
        rows.push(vec![
            name.clone(),
            if rep.admitted[m] { "yes" } else { "REJECTED" }.to_string(),
            format!("{:?}", rep.replica_map[m]),
            rep.served[m].to_string(),
            rep.rejected[m].to_string(),
            format!("{:.1}", rep.throughput[m]),
            format!("{:.1}", rep.p99_ms[m]),
            format!("{:.1}", rep.violations_per_sec[m]),
            format!("{:.0}", rep.shed_rps[m]),
        ]);
    }
    println!(
        "{}",
        dstack::util::ascii_table(
            &["model", "admitted", "gpus", "served", "rejected", "req/s", "p99_ms", "viol/s", "shed/s"],
            &rows
        )
    );
    let mut gpu_rows = Vec::new();
    for (g, gr) in rep.per_gpu.iter().enumerate() {
        let models: Vec<String> = gr
            .models
            .iter()
            .map(|s| format!("{}@{}%", names[s.model], s.pct))
            .collect();
        gpu_rows.push(vec![
            format!("gpu{g} ({})", gr.gpu),
            format!("{}%", gr.knee_load_pct),
            format!("{:.1}%", gr.utilization * 100.0),
            models.join(" "),
        ]);
    }
    println!(
        "{}",
        dstack::util::ascii_table(&["gpu", "knee_load", "util", "replicas"], &gpu_rows)
    );
    println!(
        "total {:.0} req/s over {} GPUs, mean utilization {:.1}%",
        rep.total_throughput(),
        rep.gpu_utilization.len(),
        rep.mean_utilization() * 100.0
    );
    if let Some(l) = &rep.lifecycle {
        println!(
            "memory manager: {} cold starts ({} delayed reqs, p99 delay {:.0} ms), \
             {} warm hits, {} evictions, {} scale-to-zero, {} MiB loaded ({:.0} ms)",
            l.cold_starts,
            l.cold_delayed,
            l.cold_start_p99_ms,
            l.warm_hits,
            l.evictions,
            l.scale_to_zero,
            l.mib_loaded,
            l.load_ms_total,
        );
        println!(
            "goodput {:.0} req/s in SLO; peak resident MiB per GPU {:?}; resident at horizon {:?}",
            l.goodput_rps, l.peak_resident_mib, l.resident_final
        );
    }
    if let Some(a) = &rep.adaptive {
        let cold = a
            .cold_migration_ms
            .map(|c| format!(", {c:.0} ms cold-priced"))
            .unwrap_or_default();
        println!(
            "control plane: {} replans, {} rebalances (+{} / -{} replicas, {:.0} ms migration{}) at {:?} ms",
            a.replans,
            a.rebalances,
            a.replicas_added,
            a.replicas_removed,
            a.migration_ms,
            cold,
            a.rebalance_times_us.iter().map(|t| t / 1_000).collect::<Vec<_>>()
        );
        println!(
            "p99 before/after first rebalance (ms): {:?} / {:?}",
            a.p99_before_ms.iter().map(|v| v.round()).collect::<Vec<_>>(),
            a.p99_after_ms.iter().map(|v| v.round()).collect::<Vec<_>>()
        );
    }
    if let Some(r) = &rep.resilience {
        println!(
            "resilience: {} fault events ({} engine-downs), {} rerouted on failure, \
             hedges {}/{} won, availability {:.2}%",
            r.fault_events,
            r.engine_downs,
            r.rerouted_on_failure,
            r.hedges_won,
            r.hedges_fired,
            r.availability_pct,
        );
        println!(
            "front door: {} deadline rejects (critical) + {} (bulk), {} unroutable rejects; \
             goodput in unhealthy windows {:.0} req/s",
            r.deadline_rejects_critical,
            r.deadline_rejects_bulk,
            r.unroutable_rejects,
            r.degraded_goodput_rps,
        );
    }
    if let Some(o) = &rep.overload {
        println!(
            "overload: {} retries scheduled ({} served), retry-exhausted {} critical + {} bulk, \
             breakers {} trips / {} probes / {} open rejects",
            o.retries_scheduled,
            o.retries_succeeded,
            o.retry_exhausted_critical,
            o.retry_exhausted_bulk,
            o.breaker_trips,
            o.breaker_probes,
            o.breaker_open_rejects,
        );
        println!(
            "brownout: {} degraded served (critical) + {} (bulk)",
            o.degraded_served_critical, o.degraded_served_bulk,
        );
    }
}

/// Overlay the `adaptive` tuning flags onto a base config: every flag
/// the usage text documents works both with `--config` (overriding the
/// scenario's block) and with the built-in drifting workload.
fn adaptive_cfg_from_args(
    args: &Args,
    base: dstack::controlplane::AdaptiveCfg,
) -> anyhow::Result<dstack::controlplane::AdaptiveCfg> {
    let cfg = dstack::controlplane::AdaptiveCfg {
        interval_ms: args.get_f64("interval", base.interval_ms),
        alpha: args.get_f64("alpha", base.alpha),
        drift_threshold: args.get_f64("threshold", base.drift_threshold),
        rearm_threshold: args.get_f64("rearm", base.rearm_threshold),
        cooldown_ticks: args.get_u64("cooldown", base.cooldown_ticks as u64) as u32,
        migration_cost_ms: args.get_f64("migration-cost", base.migration_cost_ms),
    };
    cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(cfg)
}

fn adaptive_cmd(args: &Args) -> anyhow::Result<()> {
    use dstack::cluster::{serve_cluster_with, GpuSched, PlacementPolicy, RoutingPolicy};
    use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive_with, AdaptiveCfg};
    if let Some(path) = args.get("config") {
        let mut sc = dstack::config::Scenario::from_file(Path::new(path))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if sc.cluster.is_none() {
            anyhow::bail!("adaptive needs a scenario with a 'cluster' block");
        }
        sc.horizon_ms = args.get_f64("horizon", sc.horizon_ms);
        sc.seed = args.get_u64("seed", sc.seed);
        overlay_exec_args(args, &mut sc)?;
        sc.adaptive =
            Some(adaptive_cfg_from_args(args, sc.adaptive.clone().unwrap_or_default())?);
        let names: Vec<String> = match sc.overload_expanded() {
            Ok(Some((profiles, _))) => profiles.iter().map(|p| p.name.clone()).collect(),
            _ => sc.profiles().iter().map(|p| p.name.clone()).collect(),
        };
        let rep = dstack::config::run_adaptive_scenario(&sc);
        println!("scenario '{}' adaptive policy={}", sc.name, rep.policy);
        print_cluster_report(&names, &rep);
        print_exec_stats(args, &rep);
        emit_obs_artifacts(args, &rep)?;
        return Ok(());
    }
    let horizon_ms = args.get_f64("horizon", 10_000.0);
    let seed = args.get_u64("seed", 42);
    let opts = exec_opts_from_args(args, dstack::cluster::ExecOpts::default())?;
    let cfg = adaptive_cfg_from_args(args, AdaptiveCfg::default())?;

    let (profiles, initial, peak, reqs) = drift_workload(horizon_ms, seed);
    let gpus = drift_gpus();
    let names: Vec<String> = profiles.iter().map(|p| p.name.clone()).collect();
    println!(
        "drifting-rate workload on 2xV100, horizon {horizon_ms:.0} ms, drift at {:.0} ms",
        horizon_ms / 2.0
    );

    let stat = serve_cluster_with(
        &profiles,
        &peak,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        reqs.clone(),
        horizon_ms,
        seed,
        opts,
    );
    println!("\n== static placement (solved once, for per-model peak rates) ==");
    print_cluster_report(&names, &stat);
    print_exec_stats(args, &stat);

    let adap = run_adaptive_with(
        &profiles,
        &initial,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        horizon_ms,
        seed,
        opts,
    );
    println!("\n== adaptive control plane ==");
    print_cluster_report(&names, &adap);
    print_exec_stats(args, &adap);
    emit_obs_artifacts(args, &adap)?;

    let (s, a) = (stat.total_throughput(), adap.total_throughput());
    println!(
        "\nadaptive vs static: {a:.0} vs {s:.0} req/s served ({:.2}x)",
        a / s.max(1e-9)
    );
    Ok(())
}

/// Names of the long-tail fleet a lifecycle scenario generates (the
/// base list cycled through `lifecycle::fleet_name`), for report rows.
fn lifecycle_fleet_names(sc: &dstack::config::Scenario) -> Vec<String> {
    let base = sc.profiles();
    let n = sc.lifecycle.as_ref().map_or(base.len(), |l| l.n_models);
    (0..n).map(|i| dstack::lifecycle::fleet_name(&base[i % base.len()].name, i)).collect()
}

fn lifecycle_cmd(args: &Args) -> anyhow::Result<()> {
    use dstack::cluster::{GpuSched, PlacementPolicy, RoutingPolicy};
    use dstack::lifecycle::{
        longtail_gpus, longtail_workload, serve_longtail_with, EvictionPolicy, LifecycleCfg,
    };
    if let Some(path) = args.get("config") {
        let mut sc = dstack::config::Scenario::from_file(Path::new(path))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if sc.cluster.is_none() || sc.lifecycle.is_none() {
            anyhow::bail!("lifecycle needs a scenario with 'cluster' and 'lifecycle' blocks");
        }
        sc.horizon_ms = args.get_f64("horizon", sc.horizon_ms);
        sc.seed = args.get_u64("seed", sc.seed);
        overlay_exec_args(args, &mut sc)?;
        {
            let lc = sc.lifecycle.as_mut().expect("checked above");
            if let Some(e) = args.get("eviction") {
                lc.cfg.eviction = EvictionPolicy::parse(e).map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            lc.cfg.mem_budget_mib = args.get_u64("mem-budget", lc.cfg.mem_budget_mib);
            if args.has_flag("oblivious") {
                lc.cfg.warm_routing = false;
            }
            lc.cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        let names = lifecycle_fleet_names(&sc);
        let rep = dstack::config::run_lifecycle_scenario(&sc);
        println!("scenario '{}' lifecycle policy={}", sc.name, rep.policy);
        print_cluster_report(&names, &rep);
        print_exec_stats(args, &rep);
        emit_obs_artifacts(args, &rep)?;
        return Ok(());
    }
    // Built-in canonical scenario: 24-model Zipf(1.1) long-tail on
    // 2×V100 whose combined resident budget holds fewer than half the
    // fleet; warmness-aware vs warm-oblivious JSQ side by side.
    let horizon_ms = args.get_f64("horizon", 8_000.0);
    let seed = args.get_u64("seed", 42);
    let opts = exec_opts_from_args(args, dstack::cluster::ExecOpts::default())?;
    let mut cfg = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };
    if let Some(e) = args.get("eviction") {
        cfg.eviction = EvictionPolicy::parse(e).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    cfg.mem_budget_mib = args.get_u64("mem-budget", cfg.mem_budget_mib);
    cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;

    let (profiles, rates, reqs) = longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = longtail_gpus();
    let names: Vec<String> = profiles.iter().map(|p| p.name.clone()).collect();
    let total_mem: u64 = profiles.iter().map(|p| p.mem_mib).sum();
    println!(
        "24-model Zipf(1.1) long-tail on 2xV100: {} MiB of weights vs {} MiB resident budget, \
         {:.0} req/s offered, horizon {horizon_ms:.0} ms",
        total_mem,
        2 * cfg.mem_budget_mib,
        600.0
    );

    let run = |warm: bool| {
        let c = LifecycleCfg { warm_routing: warm, ..cfg.clone() };
        serve_longtail_with(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &c,
            reqs.clone(),
            horizon_ms,
            seed,
            opts,
        )
    };
    if args.has_flag("oblivious") {
        let rep = run(false);
        println!("\n== warm-oblivious JSQ ==");
        print_cluster_report(&names, &rep);
        print_exec_stats(args, &rep);
        emit_obs_artifacts(args, &rep)?;
        return Ok(());
    }
    let cold = run(false);
    println!("\n== warm-oblivious JSQ ==");
    print_cluster_report(&names, &cold);
    let warm = run(true);
    println!("\n== warmness-aware JSQ ==");
    print_cluster_report(&names, &warm);
    print_exec_stats(args, &warm);
    emit_obs_artifacts(args, &warm)?;

    let (gw, gc) = (
        warm.lifecycle.as_ref().map_or(0.0, |l| l.goodput_rps),
        cold.lifecycle.as_ref().map_or(0.0, |l| l.goodput_rps),
    );
    println!(
        "\nwarmness-aware vs warm-oblivious: goodput {gw:.0} vs {gc:.0} req/s ({:.2}x), \
         viol/s {:.0} vs {:.0}",
        gw / gc.max(1e-9),
        warm.violations_per_sec.iter().sum::<f64>(),
        cold.violations_per_sec.iter().sum::<f64>()
    );
    Ok(())
}

fn unified_cmd(args: &Args) -> anyhow::Result<()> {
    use dstack::cluster::{GpuSched, PlacementPolicy, RoutingPolicy};
    use dstack::lifecycle::{serve_longtail_with, EvictionPolicy, LifecycleCfg};
    use dstack::unified::{
        drifting_longtail_workload, run_unified_with, unified_gpus, UnifiedCfg,
    };
    if let Some(path) = args.get("config") {
        let mut sc = dstack::config::Scenario::from_file(Path::new(path))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if sc.cluster.is_none() || sc.lifecycle.is_none() {
            anyhow::bail!("unified needs a scenario with 'cluster' and 'lifecycle' blocks");
        }
        sc.horizon_ms = args.get_f64("horizon", sc.horizon_ms);
        sc.seed = args.get_u64("seed", sc.seed);
        overlay_exec_args(args, &mut sc)?;
        {
            let lc = sc.lifecycle.as_mut().expect("checked above");
            if let Some(e) = args.get("eviction") {
                lc.cfg.eviction = EvictionPolicy::parse(e).map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            lc.cfg.mem_budget_mib = args.get_u64("mem-budget", lc.cfg.mem_budget_mib);
            lc.cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        // A missing "unified" block defaults on; flags override it.
        let mut un = sc.unified.clone().unwrap_or(dstack::config::UnifiedScenario {
            drift: true,
            eviction_replan_threshold: UnifiedCfg::default().eviction_replan_threshold,
        });
        un.eviction_replan_threshold =
            args.get_u64("pressure-threshold", un.eviction_replan_threshold);
        if args.has_flag("no-drift") {
            un.drift = false;
        }
        sc.unified = Some(un);
        let names = lifecycle_fleet_names(&sc);
        let rep = dstack::config::run_unified_scenario(&sc);
        println!("scenario '{}' unified policy={}", sc.name, rep.policy);
        print_cluster_report(&names, &rep);
        print_exec_stats(args, &rep);
        emit_obs_artifacts(args, &rep)?;
        return Ok(());
    }
    // Built-in canonical stress: the 24-model Zipf(1.1) long-tail whose
    // popularity ranking rotates at the midpoint, on N V100s whose
    // resident budgets force eviction pressure — the unified driver
    // (drift + pressure replans, residency-priced) against the naive
    // composition (memory manager under the frozen t = 0 plan).
    let horizon_ms = args.get_f64("horizon", 8_000.0);
    let seed = args.get_u64("seed", 42);
    let n_gpus = args.get_u64("gpus", 4) as usize;
    if n_gpus == 0 {
        anyhow::bail!("--gpus must be >= 1");
    }
    let opts = exec_opts_from_args(args, dstack::cluster::ExecOpts::default())?;
    let mut lcfg = LifecycleCfg { mem_budget_mib: 4_096, min_replicas: 1, ..Default::default() };
    if let Some(e) = args.get("eviction") {
        lcfg.eviction = EvictionPolicy::parse(e).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    lcfg.mem_budget_mib = args.get_u64("mem-budget", lcfg.mem_budget_mib);
    lcfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = UnifiedCfg {
        lifecycle: lcfg.clone(),
        eviction_replan_threshold: args.get_u64("pressure-threshold", 8),
        ..Default::default()
    };

    let (profiles, rates, reqs) = drifting_longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = unified_gpus(n_gpus);
    let names: Vec<String> = profiles.iter().map(|p| p.name.clone()).collect();
    let total_mem: u64 = profiles.iter().map(|p| p.mem_mib).sum();
    println!(
        "24-model rotating Zipf(1.1) on {n_gpus}xV100: {} MiB of weights vs {} MiB resident \
         budget, 600 req/s offered, popularity rotates at {:.0} ms, horizon {horizon_ms:.0} ms",
        total_mem,
        n_gpus as u64 * cfg.lifecycle.mem_budget_mib,
        horizon_ms / 2.0
    );

    let naive = serve_longtail_with(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &lcfg,
        reqs.clone(),
        horizon_ms,
        seed,
        opts,
    );
    println!("\n== naive composition: memory manager under the frozen t=0 plan ==");
    print_cluster_report(&names, &naive);

    let uni = run_unified_with(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        horizon_ms,
        seed,
        opts,
    );
    println!("\n== unified control plane: residency-priced drift + pressure replans ==");
    print_cluster_report(&names, &uni);
    print_exec_stats(args, &uni);
    emit_obs_artifacts(args, &uni)?;

    let (gu, gn) = (
        uni.lifecycle.as_ref().map_or(0.0, |l| l.goodput_rps),
        naive.lifecycle.as_ref().map_or(0.0, |l| l.goodput_rps),
    );
    println!(
        "\nunified vs naive composition: goodput {gu:.0} vs {gn:.0} req/s ({:.2}x), \
         viol/s {:.0} vs {:.0}",
        gu / gn.max(1e-9),
        uni.violations_per_sec.iter().sum::<f64>(),
        naive.violations_per_sec.iter().sum::<f64>()
    );
    Ok(())
}

fn cluster_cmd(args: &Args) -> anyhow::Result<()> {
    use dstack::cluster::{fig12_specs, serve_cluster_stream, GpuSched, PlacementPolicy, RoutingPolicy};
    use dstack::workload::{bursty_arrivals, Arrivals, MergedStream, TraceSpec, TraceStream, UnsortedPolicy};
    let gpu_names = args.get_or("gpus", "T4,T4,T4,T4");
    let mut gpus = Vec::new();
    for n in gpu_names.split(',') {
        let n = n.trim();
        let spec = dstack::profile::GpuSpec::by_name(n)
            .ok_or_else(|| anyhow::anyhow!("unknown gpu '{n}'"))?;
        gpus.push(spec.clone());
    }
    let placement = PlacementPolicy::parse(args.get_or("placement", "ffd"))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let routing = RoutingPolicy::parse(args.get_or("routing", "jsq"))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let sched =
        GpuSched::parse(args.get_or("sched", "dstack")).map_err(|e| anyhow::anyhow!("{e}"))?;
    let horizon_ms = args.get_f64("horizon", 8_000.0);
    let seed = args.get_u64("seed", 77);
    let opts = exec_opts_from_args(args, dstack::cluster::ExecOpts::default())?;

    // The Fig. 12 asymmetric-demand model mix over the chosen cluster;
    // arrivals stream lazily from a recorded trace (`--trace`), a bursty
    // generator (`--workload mmpp|diurnal|flash`), or Poisson (default).
    let (profiles, rates, _) = fig12_specs();
    let rep = if let Some(tp) = args.get("trace") {
        let policy = UnsortedPolicy::parse(args.get_or("on-unsorted", "reject"))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let spec = TraceSpec {
            models: profiles.iter().map(|p| (p.name.clone(), p.slo_ms)).collect(),
            horizon_ms,
            policy,
        };
        let stream =
            TraceStream::open(Path::new(tp), &spec).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("replaying trace {tp} ({} requests in horizon)", stream.total_requests());
        serve_cluster_stream(
            &profiles, &rates, &gpus, placement, routing, sched, stream, horizon_ms, seed, opts,
        )
    } else {
        let kind = args.get_or("workload", "poisson");
        let specs: Vec<(Arrivals, f64)> = profiles
            .iter()
            .zip(&rates)
            .map(|(p, &r)| {
                bursty_arrivals(kind, r, horizon_ms)
                    .map(|a| (a, p.slo_ms))
                    .map_err(|e| anyhow::anyhow!("{e}"))
            })
            .collect::<Result<_, _>>()?;
        let stream = MergedStream::new(&specs, horizon_ms, seed);
        serve_cluster_stream(
            &profiles, &rates, &gpus, placement, routing, sched, stream, horizon_ms, seed, opts,
        )
    };
    println!(
        "cluster [{}] placement={} routing={} sched={} workload={} horizon={:.0}ms",
        gpu_names,
        placement.name(),
        routing.name(),
        sched.name(),
        args.get("trace").map(|_| "trace").unwrap_or(args.get_or("workload", "poisson")),
        horizon_ms
    );
    let model_names: Vec<String> = profiles.iter().map(|p| p.name.clone()).collect();
    print_cluster_report(&model_names, &rep);
    print_exec_stats(args, &rep);
    emit_obs_artifacts(args, &rep)?;
    Ok(())
}

fn optimize(args: &Args) -> anyhow::Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow::anyhow!("--model required"))?;
    let mut m = dstack::profile::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
    if let Some(slo) = args.get("slo") {
        m.slo_ms = slo.parse()?;
    }
    let cfg = dstack::optimizer::OptConfig::default();
    match dstack::optimizer::optimize(&m, &dstack::profile::V100, &cfg) {
        Some(p) => println!(
            "{name}: batch {} @ {}% GPU — latency {:.1} ms, throughput {:.0}/s, η {:.2} (slo {} ms)",
            p.batch, p.gpu_pct, p.latency_ms, p.throughput, p.efficacy, m.slo_ms
        ),
        None => println!("{name}: no feasible operating point under SLO {} ms", m.slo_ms),
    }
    Ok(())
}

fn profile_cmd(args: &Args) -> anyhow::Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow::anyhow!("--model required"))?;
    let b = args.get_u64("batch", 16) as u32;
    let m = dstack::profile::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
    println!("{name} (batch {b}): latency vs GPU% on V100");
    for pct in (5..=100).step_by(5) {
        let l = m.latency_ms(pct, b);
        let marker = if pct == m.knee_pct_on(&dstack::profile::V100, b) { "  <- knee" } else { "" };
        println!("  {pct:>3}%  {l:>8.2} ms{marker}");
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    use dstack::coordinator::{Coordinator, ServeConfig, ServeModel, ServePolicy};
    let seconds = args.get_f64("seconds", 10.0);
    let scale = args.get_f64("rate-scale", 1.0);
    let policy = match args.get_or("policy", "dstack") {
        "fifo" => ServePolicy::Fifo,
        _ => ServePolicy::DstackRt,
    };
    let rt = dstack::runtime::Runtime::new(&dstack::runtime::artifacts_dir())?;
    let mut coord = Coordinator::new(rt);
    let cfg = ServeConfig {
        models: vec![
            ServeModel { name: "mobilenet_mini".into(), rate: 60.0 * scale, slo_ms: 100.0 },
            ServeModel { name: "alexnet_mini".into(), rate: 60.0 * scale, slo_ms: 100.0 },
            ServeModel { name: "resnet_mini".into(), rate: 30.0 * scale, slo_ms: 200.0 },
            ServeModel { name: "vgg_mini".into(), rate: 15.0 * scale, slo_ms: 400.0 },
        ],
        policy,
        duration: std::time::Duration::from_secs_f64(seconds),
        seed: args.get_u64("seed", 42),
    };
    let rep = coord.serve(&cfg)?;
    println!("{}", rep.render());
    println!(
        "total {:.0} req/s, violation fraction {:.3}",
        rep.total_throughput(),
        rep.violation_fraction()
    );
    Ok(())
}

fn selfcheck() -> anyhow::Result<()> {
    let mut rt = dstack::runtime::Runtime::new(&dstack::runtime::artifacts_dir())?;
    let n = rt.load_all_checked()?;
    println!("all {n} artifacts compiled + numerics verified against JAX");
    Ok(())
}
