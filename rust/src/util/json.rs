//! Minimal JSON implementation (parser + serializer).
//!
//! The build image has no reachable crates registry, so `serde`/
//! `serde_json` are unavailable; configs, the AOT artifact manifest and
//! figure-data interchange all use this module instead. It implements
//! RFC 8259 JSON with the usual lenient extras disabled (no comments, no
//! trailing commas).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order), which keeps artifact manifests and
/// golden-file tests reproducible.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`Json::parse`], carrying a byte offset and message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers for config parsing with decent errors.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing required field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?.as_f64().ok_or_else(|| format!("field '{key}' must be a number"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?.as_str().ok_or_else(|| format!("field '{key}' must be a string"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| format!("field '{key}' must be a non-negative integer"))
    }

    /// Optional field with default.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Structural equality with a numeric tolerance: numbers compare as
    /// `|a − b| ≤ tol · max(1, |a|, |b|)`, everything else exactly. The
    /// golden-trace regression tests diff reports through this, so
    /// platform-level float formatting noise cannot produce false
    /// failures while any real drift (counts, added/removed fields,
    /// reordered arrays) still does.
    pub fn approx_eq(&self, other: &Json, tol: f64) -> bool {
        match (self, other) {
            (Json::Num(a), Json::Num(b)) => {
                (a - b).abs() <= tol * 1f64.max(a.abs()).max(b.abs())
            }
            (Json::Arr(a), Json::Arr(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.approx_eq(y, tol))
            }
            (Json::Obj(a), Json::Obj(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|((ka, va), (kb, vb))| {
                        ka == kb && va.approx_eq(vb, tol)
                    })
            }
            (a, b) => a == b,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// [`Self::obj`] for keys computed at runtime (owned strings).
    pub fn obj_owned(pairs: Vec<(String, Json)>) -> Json {
        Json::Obj(pairs.into_iter().collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_str(values: &[&str]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Str(v.to_string())).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like most serializers in lenient mode.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            // Fast path: consume a run of plain bytes.
            while let Some(b) = self.peek() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > start {
                s.push_str(
                    std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8 in string"))?,
                );
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // Surrogate pair.
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let low = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&low) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => return Err(self.err("control character in string")),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let txt = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // Surrogate pair for U+1F600.
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"b":[1,2.5,true,null],"a":{"k":"v"},"s":"q\"uote"}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(10.0).to_string_compact(), "10");
        assert_eq!(Json::Num(10.25).to_string_compact(), "10.25");
    }

    #[test]
    fn approx_eq_tolerates_float_noise_only() {
        let a = Json::parse(r#"{"x": [1.0, 2.0], "n": 10, "s": "p99"}"#).unwrap();
        let close = Json::parse(r#"{"x": [1.0000000001, 2.0], "n": 10, "s": "p99"}"#).unwrap();
        let far = Json::parse(r#"{"x": [1.1, 2.0], "n": 10, "s": "p99"}"#).unwrap();
        let renamed = Json::parse(r#"{"x": [1.0, 2.0], "n": 10, "s": "p98"}"#).unwrap();
        let extra = Json::parse(r#"{"x": [1.0, 2.0], "n": 10, "s": "p99", "y": 0}"#).unwrap();
        assert!(a.approx_eq(&close, 1e-6));
        assert!(!a.approx_eq(&far, 1e-6));
        assert!(!a.approx_eq(&renamed, 1e-6));
        assert!(!a.approx_eq(&extra, 1e-6));
        // Tolerance is relative for large magnitudes.
        let big = Json::Num(1e12);
        assert!(big.approx_eq(&Json::Num(1e12 + 100.0), 1e-6));
        assert!(!big.approx_eq(&Json::Num(1.01e12), 1e-6));
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "f": 1.5, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 7);
        assert!(v.req_u64("f").is_err());
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_str("missing").is_err());
        assert_eq!(v.opt_f64("absent", 9.0), 9.0);
        assert!(v.opt_bool("b", false));
    }
}
