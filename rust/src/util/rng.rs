//! Deterministic PRNG + distributions.
//!
//! `rand` is not available offline, so workload generation, weight
//! initialization and the property-test harness use this PCG32
//! implementation (O'Neill 2014, `pcg32_random_r` with fixed stream).
//! Everything downstream seeds explicitly, which keeps all simulations
//! and experiments bit-reproducible.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-argument constructor used through most of the codebase.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random bits / 2^53.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// simulation purposes via rejection).
    pub fn u32_below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "u32_below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (n as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // Full u64 span.
            return self.next_u64() as i64;
        }
        lo + (self.next_u64() % span) as i64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.u32_below(n as u32) as usize
    }

    /// Exponential inter-arrival sample with the given rate (events/unit).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        // Avoid ln(0).
        let u = 1.0 - self.f64();
        -u.ln() / rate
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = (lambda + lambda.sqrt() * self.normal()).round();
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_below(i + 1);
            items.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_below(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg32::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Pcg32::seeded(1);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Pcg32::seeded(2);
        let rate = 250.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.0005, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Pcg32::seeded(3);
        for &lambda in &[2.5, 80.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() / lambda < 0.05, "lambda {lambda} mean {mean}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(4);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn u32_below_bounds_and_coverage() {
        let mut r = Pcg32::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.u32_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "50-element shuffle left input unchanged");
    }
}
