//! Streaming and batch statistics used by the metrics layer and the
//! in-tree bench harness (criterion is unavailable offline).

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Percentile of a sample set (linear interpolation between order stats).
/// `q` is in `[0, 100]`. Returns 0.0 on empty input.
///
/// O(n) via quickselect (`select_nth_unstable_by`) instead of a full
/// O(n log n) sort: this runs once per model per report over latency
/// vectors that grow with the horizon, and only the two order
/// statistics around the rank are ever needed. Results are bit-identical
/// to sorting first (the same order statistics feed the same
/// interpolation).
///
/// NaN ordering: comparisons use [`f64::total_cmp`], under which NaN
/// sorts *after* `+inf` (for the positive-sign NaN bit patterns the
/// arithmetic here produces). A NaN sample therefore lands in the top
/// order statistics and poisons only the highest percentiles instead of
/// panicking mid-report — the serving loop survives a corrupt latency
/// estimate. NaN-free inputs are unaffected: `total_cmp` agrees with
/// the old `partial_cmp().unwrap()` on every ordinary value.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut scratch: Vec<f64> = samples.to_vec();
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (scratch.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let frac = rank - lo as f64;
    let (_, &mut lo_v, rest) = scratch.select_nth_unstable_by(lo, |a, b| a.total_cmp(b));
    if frac == 0.0 {
        return lo_v;
    }
    // The (lo+1)-th order statistic is the minimum of the partition
    // right of the pivot (non-empty whenever frac > 0).
    let hi_v = rest.iter().copied().fold(f64::INFINITY, f64::min);
    lo_v * (1.0 - frac) + hi_v * frac
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Jain's fairness index: (Σx)² / (n·Σx²). 1.0 = perfectly fair.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

/// Fixed-bin histogram over `[lo, hi)`; out-of-range samples clamp to the
/// edge bins so totals are conserved.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            n - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize
        };
        self.bins[idx.min(n - 1)] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Approximate quantile from bin boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                let w = (self.hi - self.lo) / self.bins.len() as f64;
                return self.lo + w * (i as f64 + 0.5);
            }
        }
        self.hi
    }
}

/// Summary of a latency sample used in reports and bench output.
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// NaN samples sort last ([`f64::total_cmp`]) — they skew `max` and
    /// the top percentiles instead of panicking the report path.
    pub fn from_samples(samples: &[f64]) -> Summary {
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mean = if sorted.is_empty() {
            0.0
        } else {
            sorted.iter().sum::<f64>() / sorted.len() as f64
        };
        Summary {
            count: sorted.len(),
            mean,
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            min: sorted.first().copied().unwrap_or(0.0),
            max: sorted.last().copied().unwrap_or(0.0),
        }
    }
}

/// Log-bucketed histogram with ~±1% relative error and bounded memory —
/// the streaming replacement for materializing one `f64` per served
/// request (`ModelMetrics::latencies_ms`) at 10⁷-request scale.
///
/// Buckets grow geometrically by [`LogHistogram::GROWTH`] (2%/bucket);
/// a sample is reported as the geometric midpoint of its bucket, so the
/// relative error is at most `√GROWTH − 1 ≈ 1%`. Storage is a sparse
/// `BTreeMap<bucket, count>` — for latencies spanning 1 µs..100 s
/// that is at most ~930 live buckets, independent of sample count.
/// Non-positive and non-finite samples land in a dedicated underflow
/// bucket (reported as `min`), so totals are conserved. `min`, `max`
/// and the mean are tracked exactly; only interior quantiles are
/// approximate. Mergeable across engines/windows ([`Self::merge`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LogHistogram {
    buckets: std::collections::BTreeMap<i32, u64>,
    underflow: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    /// Geometric bucket growth factor.
    pub const GROWTH: f64 = 1.02;

    fn bucket_of(x: f64) -> i32 {
        (x.ln() / Self::GROWTH.ln()).floor() as i32
    }

    /// Geometric midpoint of bucket `b` — the reported value for any
    /// sample that landed there.
    fn bucket_mid(b: i32) -> f64 {
        Self::GROWTH.powf(b as f64 + 0.5)
    }

    pub fn push(&mut self, x: f64) {
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            if x < self.min {
                self.min = x;
            }
            if x > self.max {
                self.max = x;
            }
        }
        self.count += 1;
        self.sum += x;
        if x > 0.0 && x.is_finite() {
            *self.buckets.entry(Self::bucket_of(x)).or_insert(0) += 1;
        } else {
            self.underflow += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Live buckets (the memory footprint proxy).
    pub fn n_buckets(&self) -> usize {
        self.buckets.len() + usize::from(self.underflow > 0)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Absorb another histogram (per-engine → cluster, per-window →
    /// run). Exact for counts/sum; min/max stay exact.
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.count += other.count;
        self.sum += other.sum;
        self.underflow += other.underflow;
        for (&b, &c) in &other.buckets {
            *self.buckets.entry(b).or_insert(0) += c;
        }
    }

    /// Quantile `q ∈ [0, 1]` with ≤ ~1% relative error, clamped into
    /// `[min, max]` so the histogram can never report outside the
    /// observed range.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.min();
        }
        for (&b, &c) in &self.buckets {
            acc += c;
            if acc >= target {
                return Self::bucket_mid(b).clamp(self.min, self.max);
            }
        }
        self.max()
    }

    /// [`Summary`] computed from the histogram — the bounded-memory
    /// substitute for [`Summary::from_samples`] when exact latency
    /// vectors are disabled (`observability.exact_latencies = false`).
    pub fn summary(&self) -> Summary {
        Summary {
            count: self.count as usize,
            mean: self.mean(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            min: self.min(),
            max: self.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((o.mean() - mean).abs() < 1e-12);
        let var: f64 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((o.variance() - var).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 9.0);
        assert_eq!(o.count(), 8);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 90.0), 7.0);
    }

    #[test]
    fn percentile_matches_sorted_reference() {
        // Quickselect path vs the sort-based reference: bit-identical
        // on unsorted, duplicate-heavy input across the whole q range.
        let mut xs = Vec::new();
        let mut state = 0x9E37u64;
        for _ in 0..257 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            xs.push(((state >> 33) % 1000) as f64 / 7.0);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 0.1, 25.0, 50.0, 63.7, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(percentile(&xs, q), percentile_sorted(&sorted, q), "q={q}");
        }
    }

    #[test]
    fn percentile_survives_nan_and_stays_exact_without() {
        // A NaN sample must not panic the quickselect comparator (the
        // old partial_cmp().unwrap() aborted the whole report); under
        // total_cmp it sorts past +inf, so low/mid percentiles of the
        // clean prefix are still returned.
        let poisoned = [3.0, f64::NAN, 1.0, 2.0, 4.0];
        let p0 = percentile(&poisoned, 0.0);
        assert_eq!(p0, 1.0);
        let p25 = percentile(&poisoned, 25.0);
        assert_eq!(p25, 2.0);
        // The top percentile interpolates against the NaN order stat.
        assert!(percentile(&poisoned, 100.0).is_nan());
        // Summary over NaN-bearing samples must not panic either.
        let s = Summary::from_samples(&poisoned);
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 1.0);
        // Golden-safety: on NaN-free input the total_cmp comparator is
        // bit-identical to the old partial_cmp path (they agree on every
        // ordinary float), including signed zeros and duplicates.
        let clean = [0.25, -0.0, 0.0, 7.5, 0.25, 1e-300, -3.0, 7.5];
        let mut sorted = clean.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(
                percentile(&clean, q).to_bits(),
                percentile_sorted(&sorted, q).to_bits(),
                "q={q}"
            );
        }
    }

    #[test]
    fn jain_index() {
        assert!((jain_fairness(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        // One user hogging everything among n: index = 1/n.
        assert!((jain_fairness(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        assert_eq!(jain_fairness(&[]), 1.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.push((i % 100) as f64);
        }
        assert_eq!(h.count(), 1000);
        let q50 = h.quantile(0.5);
        assert!((q50 - 50.0).abs() < 2.0, "q50 {q50}");
        // Clamping.
        h.push(-5.0);
        h.push(500.0);
        assert_eq!(h.count(), 1002);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn log_histogram_quantiles_within_one_percent() {
        // Uniform latencies over 1..10_000 ms: every quantile estimate
        // must land within the advertised √1.02−1 ≈ 1% relative error
        // of the exact order statistic.
        let xs: Vec<f64> = (1..=10_000).map(|i| i as f64).collect();
        let mut h = LogHistogram::default();
        for &x in &xs {
            h.push(x);
        }
        assert_eq!(h.count(), 10_000);
        for q in [0.01, 0.1, 0.5, 0.9, 0.99, 0.999] {
            let exact = percentile(&xs, q * 100.0);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.011, "q={q}: exact {exact} approx {approx} rel {rel}");
        }
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 10_000.0);
        assert!((h.mean() - 5_000.5).abs() < 1e-9);
        // Memory is bucket-bound, not sample-bound.
        assert!(h.n_buckets() < 600, "{} buckets", h.n_buckets());
    }

    #[test]
    fn log_histogram_merge_equals_combined_push() {
        let mut a = LogHistogram::default();
        let mut b = LogHistogram::default();
        let mut all = LogHistogram::default();
        for i in 1..500 {
            let x = (i * i % 977) as f64 + 0.5;
            if i % 2 == 0 { a.push(x) } else { b.push(x) }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a, all, "merge must equal pushing the union");
        for q in [0.5, 0.99] {
            assert_eq!(a.quantile(q), all.quantile(q));
        }
    }

    #[test]
    fn log_histogram_edge_cases() {
        let h = LogHistogram::default();
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.summary().count, 0);
        let mut h = LogHistogram::default();
        h.push(0.0); // non-positive → underflow bucket
        h.push(-3.0);
        h.push(f64::INFINITY);
        h.push(5.0);
        assert_eq!(h.count(), 4, "totals conserved across underflow");
        assert_eq!(h.quantile(0.1), -3.0, "low quantiles report min for underflow mass");
        // Single-sample histogram reports the sample, clamped exactly.
        let mut one = LogHistogram::default();
        one.push(42.0);
        assert_eq!(one.quantile(0.5), 42.0);
        assert_eq!(one.summary().p99, 42.0);
    }
}
