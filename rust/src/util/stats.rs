//! Streaming and batch statistics used by the metrics layer and the
//! in-tree bench harness (criterion is unavailable offline).

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Percentile of a sample set (linear interpolation between order stats).
/// `q` is in `[0, 100]`. Returns 0.0 on empty input.
///
/// O(n) via quickselect (`select_nth_unstable_by`) instead of a full
/// O(n log n) sort: this runs once per model per report over latency
/// vectors that grow with the horizon, and only the two order
/// statistics around the rank are ever needed. Results are bit-identical
/// to sorting first (the same order statistics feed the same
/// interpolation).
///
/// NaN ordering: comparisons use [`f64::total_cmp`], under which NaN
/// sorts *after* `+inf` (for the positive-sign NaN bit patterns the
/// arithmetic here produces). A NaN sample therefore lands in the top
/// order statistics and poisons only the highest percentiles instead of
/// panicking mid-report — the serving loop survives a corrupt latency
/// estimate. NaN-free inputs are unaffected: `total_cmp` agrees with
/// the old `partial_cmp().unwrap()` on every ordinary value.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut scratch: Vec<f64> = samples.to_vec();
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (scratch.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let frac = rank - lo as f64;
    let (_, &mut lo_v, rest) = scratch.select_nth_unstable_by(lo, |a, b| a.total_cmp(b));
    if frac == 0.0 {
        return lo_v;
    }
    // The (lo+1)-th order statistic is the minimum of the partition
    // right of the pivot (non-empty whenever frac > 0).
    let hi_v = rest.iter().copied().fold(f64::INFINITY, f64::min);
    lo_v * (1.0 - frac) + hi_v * frac
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Jain's fairness index: (Σx)² / (n·Σx²). 1.0 = perfectly fair.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

/// Fixed-bin histogram over `[lo, hi)`; out-of-range samples clamp to the
/// edge bins so totals are conserved.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            n - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize
        };
        self.bins[idx.min(n - 1)] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Approximate quantile from bin boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                let w = (self.hi - self.lo) / self.bins.len() as f64;
                return self.lo + w * (i as f64 + 0.5);
            }
        }
        self.hi
    }
}

/// Summary of a latency sample used in reports and bench output.
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// NaN samples sort last ([`f64::total_cmp`]) — they skew `max` and
    /// the top percentiles instead of panicking the report path.
    pub fn from_samples(samples: &[f64]) -> Summary {
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mean = if sorted.is_empty() {
            0.0
        } else {
            sorted.iter().sum::<f64>() / sorted.len() as f64
        };
        Summary {
            count: sorted.len(),
            mean,
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            min: sorted.first().copied().unwrap_or(0.0),
            max: sorted.last().copied().unwrap_or(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((o.mean() - mean).abs() < 1e-12);
        let var: f64 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((o.variance() - var).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 9.0);
        assert_eq!(o.count(), 8);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 90.0), 7.0);
    }

    #[test]
    fn percentile_matches_sorted_reference() {
        // Quickselect path vs the sort-based reference: bit-identical
        // on unsorted, duplicate-heavy input across the whole q range.
        let mut xs = Vec::new();
        let mut state = 0x9E37u64;
        for _ in 0..257 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            xs.push(((state >> 33) % 1000) as f64 / 7.0);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 0.1, 25.0, 50.0, 63.7, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(percentile(&xs, q), percentile_sorted(&sorted, q), "q={q}");
        }
    }

    #[test]
    fn percentile_survives_nan_and_stays_exact_without() {
        // A NaN sample must not panic the quickselect comparator (the
        // old partial_cmp().unwrap() aborted the whole report); under
        // total_cmp it sorts past +inf, so low/mid percentiles of the
        // clean prefix are still returned.
        let poisoned = [3.0, f64::NAN, 1.0, 2.0, 4.0];
        let p0 = percentile(&poisoned, 0.0);
        assert_eq!(p0, 1.0);
        let p25 = percentile(&poisoned, 25.0);
        assert_eq!(p25, 2.0);
        // The top percentile interpolates against the NaN order stat.
        assert!(percentile(&poisoned, 100.0).is_nan());
        // Summary over NaN-bearing samples must not panic either.
        let s = Summary::from_samples(&poisoned);
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 1.0);
        // Golden-safety: on NaN-free input the total_cmp comparator is
        // bit-identical to the old partial_cmp path (they agree on every
        // ordinary float), including signed zeros and duplicates.
        let clean = [0.25, -0.0, 0.0, 7.5, 0.25, 1e-300, -3.0, 7.5];
        let mut sorted = clean.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(
                percentile(&clean, q).to_bits(),
                percentile_sorted(&sorted, q).to_bits(),
                "q={q}"
            );
        }
    }

    #[test]
    fn jain_index() {
        assert!((jain_fairness(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        // One user hogging everything among n: index = 1/n.
        assert!((jain_fairness(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        assert_eq!(jain_fairness(&[]), 1.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.push((i % 100) as f64);
        }
        assert_eq!(h.count(), 1000);
        let q50 = h.quantile(0.5);
        assert!((q50 - 50.0).abs() < 2.0, "q50 {q50}");
        // Clamping.
        h.push(-5.0);
        h.push(500.0);
        assert_eq!(h.count(), 1002);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }
}
