//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `subcommand --key value --flag positional` style, which is
//! all the `dstack` binary and the examples need.

use std::collections::BTreeMap;

/// Parsed arguments: one optional subcommand, `--key value` options,
/// bare `--flag`s, and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (usually
    /// `std::env::args().skip(1)`). The first non-option token becomes
    /// the subcommand; later bare tokens are positionals. A token after
    /// `--key` is consumed as its value unless it also starts with `--`,
    /// in which case `key` is recorded as a flag.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // `--key=value` form.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                match iter.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = iter.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_options_flags_positionals() {
        // NB: bare flags must come after positionals (or use `--flag=1`),
        // since `--key value` binds greedily.
        let a = parse(&["simulate", "--seed", "42", "scenario.json", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get_u64("seed", 0), 42);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["scenario.json"]);
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse(&["figures", "--fig=9", "--out=results"]);
        assert_eq!(a.get("fig"), Some("9"));
        assert_eq!(a.get_or("out", "x"), "results");
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn adjacent_flags() {
        let a = parse(&["run", "--fast", "--trace"]);
        assert!(a.has_flag("fast"));
        assert!(a.has_flag("trace"));
        assert!(a.options.is_empty());
    }
}
