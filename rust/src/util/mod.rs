//! Hand-rolled substrates: the build image has no reachable crates
//! registry, so JSON, PRNG, statistics, CLI parsing and property testing
//! are implemented in-tree (see DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

use std::path::Path;

/// Write `content` to `path`, creating parent directories.
pub fn write_file(path: &Path, content: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}

/// Render rows as an aligned ASCII table (used by the figures/tables CLI).
pub fn ascii_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate().take(ncol) {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(&format!("{:<width$}", c, width = widths[i]));
        }
        out.push('\n');
    };
    line(&mut out, &header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    line(&mut out, &sep);
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// Serialize rows to CSV (figure data interchange for plotting).
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let esc = |s: &str| {
        if s.contains(',') || s.contains('"') || s.contains('\n') {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    };
    let mut out = String::new();
    out.push_str(&header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_table_aligns() {
        let t = ascii_table(
            &["model", "knee"],
            &[
                vec!["mobilenet".into(), "20".into()],
                vec!["vgg19".into(), "50".into()],
            ],
        );
        assert!(t.contains("model"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn csv_escapes() {
        let c = to_csv(&["a", "b"], &[vec!["x,y".into(), "q\"z".into()]]);
        assert!(c.contains("\"x,y\""));
        assert!(c.contains("\"q\"\"z\""));
    }
}
