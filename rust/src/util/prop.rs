//! Mini property-based-testing harness.
//!
//! `proptest` is unavailable offline; this provides the subset the test
//! suite needs: seeded random case generation, a fixed case budget, and
//! failure reporting that includes the reproducing seed. There is no
//! shrinking — failures print the seed, and `Cases::seed(s)` replays it.

use crate::util::rng::Pcg32;

/// Configuration for a property run.
pub struct Cases {
    pub n: usize,
    pub base_seed: u64,
}

impl Default for Cases {
    fn default() -> Self {
        Cases { n: 256, base_seed: 0xD57ACC }
    }
}

impl Cases {
    pub fn new(n: usize) -> Self {
        Cases { n, ..Default::default() }
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }

    /// Run `prop` for each case with a fresh deterministic generator.
    /// Panics (failing the test) with the case seed on the first failure.
    pub fn run<F>(&self, mut prop: F)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        for i in 0..self.n {
            let case_seed = self.base_seed.wrapping_add(i as u64);
            let mut g = Gen { rng: Pcg32::seeded(case_seed), seed: case_seed };
            if let Err(msg) = prop(&mut g) {
                panic!(
                    "property failed on case {i} (replay with Cases::new(1).seed({case_seed})): {msg}"
                );
            }
        }
    }
}

/// Per-case value generator handed to properties.
pub struct Gen {
    rng: Pcg32,
    pub seed: u64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.usize_below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choice(xs)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// A subset of `xs` with at least `min` elements.
    pub fn subset<T: Clone>(&mut self, xs: &[T], min: usize) -> Vec<T> {
        assert!(min <= xs.len());
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        self.rng.shuffle(&mut idx);
        let k = self.usize_in(min, xs.len());
        idx.truncate(k);
        idx.sort();
        idx.into_iter().map(|i| xs[i].clone()).collect()
    }
}

/// Assertion helpers producing `Result<(), String>` for use in properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        Cases::new(57).run(|_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 57);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        Cases::new(16).run(|g| {
            let v = g.usize_in(0, 9);
            prop_assert!(v < 8, "v was {v}");
            Ok(())
        });
    }

    #[test]
    fn generators_respect_bounds() {
        Cases::new(200).run(|g| {
            let lo = g.usize_in(0, 5);
            let hi = lo + g.usize_in(0, 10);
            let v = g.usize_in(lo, hi);
            prop_assert!(v >= lo && v <= hi, "bounds violated: {lo} {v} {hi}");
            let f = g.f64_in(-2.0, 3.0);
            prop_assert!((-2.0..3.0).contains(&f));
            let s = g.subset(&[1, 2, 3, 4, 5], 2);
            prop_assert!(s.len() >= 2 && s.len() <= 5);
            Ok(())
        });
    }
}
