//! In-tree micro/macro benchmark harness (criterion is unavailable
//! offline). Provides warmup, a time- or iteration-bounded measurement
//! loop, robust summary statistics and throughput reporting. Bench
//! binaries under `rust/benches/` use `harness = false` and call into
//! this module, so `cargo bench` works end to end.

use crate::util::json::Json;
use crate::util::stats::{percentile_sorted, Online};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Optional units-processed-per-iteration for throughput reporting.
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / (self.mean_ns * 1e-9))
    }

    /// Machine-readable form for `BENCH_<name>.json` summaries.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::from(self.name.as_str())),
            ("iters", Json::from(self.iters)),
            ("mean_ns", Json::from(self.mean_ns)),
            ("stddev_ns", Json::from(self.stddev_ns)),
            ("p50_ns", Json::from(self.p50_ns)),
            ("p99_ns", Json::from(self.p99_ns)),
            ("min_ns", Json::from(self.min_ns)),
            ("max_ns", Json::from(self.max_ns)),
        ];
        if let Some(tp) = self.throughput_per_sec() {
            pairs.push(("throughput_per_sec", Json::from(tp)));
        }
        Json::obj(pairs)
    }
}

/// Results recorded by [`bench`] in this process, drained by
/// [`write_summary`]. Bench binaries run single-threaded, so ordering
/// is the call order.
static RESULTS: Mutex<Vec<BenchResult>> = Mutex::new(Vec::new());

/// Record a result for the process-wide summary (called by [`bench`];
/// call directly when using [`Bench::run`] without the helper).
pub fn record(r: &BenchResult) {
    RESULTS.lock().unwrap().push(r.clone());
}

/// Drain every result recorded so far into `dir/BENCH_<stem>.json` —
/// the machine-readable perf trajectory CI uploads as a workflow
/// artifact. Returns the written path.
pub fn write_summary(dir: &Path, stem: &str) -> std::io::Result<PathBuf> {
    let results: Vec<BenchResult> = std::mem::take(&mut *RESULTS.lock().unwrap());
    let json = Json::obj(vec![
        ("bench", Json::from(stem)),
        ("results", Json::Arr(results.iter().map(|r| r.to_json()).collect())),
    ]);
    let path = dir.join(format!("BENCH_{stem}.json"));
    crate::util::write_file(&path, &json.to_string_pretty())?;
    Ok(path)
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
    units_per_iter: Option<f64>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 1_000_000,
            units_per_iter: None,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            ..Default::default()
        }
    }

    /// Declare that each iteration processes `units` items (requests,
    /// events, images…) so the report includes a throughput figure.
    pub fn units(mut self, units: f64) -> Self {
        self.units_per_iter = Some(units);
        self
    }

    /// Builder-style warmup override (macro-benches with long iters).
    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    /// Builder-style measurement-window override.
    pub fn measure(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    /// Builder-style iteration bounds override.
    pub fn iters(mut self, min: u64, max: u64) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Run `f` repeatedly and collect per-iteration wall times.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples: Vec<f64> = Vec::new();
        let mut online = Online::new();
        let m0 = Instant::now();
        let mut iters = 0u64;
        while (m0.elapsed() < self.measure || iters < self.min_iters) && iters < self.max_iters {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed().as_nanos() as f64;
            samples.push(dt);
            online.push(dt);
            iters += 1;
        }
        // Timer deltas are never NaN; total_cmp keeps the same order
        // without a panicking unwrap in the measurement loop.
        samples.sort_by(|a, b| a.total_cmp(b));
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: online.mean(),
            stddev_ns: online.stddev(),
            p50_ns: percentile_sorted(&samples, 50.0),
            p99_ns: percentile_sorted(&samples, 99.0),
            min_ns: online.min(),
            max_ns: online.max(),
            units_per_iter: self.units_per_iter,
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print one result row in a stable, greppable format.
pub fn report(r: &BenchResult) {
    let mut line = format!(
        "bench {:40} iters {:>8}  mean {:>12}  p50 {:>12}  p99 {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns),
    );
    if let Some(tp) = r.throughput_per_sec() {
        line.push_str(&format!("  thpt {:>12.0}/s", tp));
    }
    println!("{line}");
}

/// Run, report, and record for the summary file (the common pattern in
/// bench binaries).
pub fn bench<F: FnMut()>(name: &str, cfg: &Bench, f: F) -> BenchResult {
    let r = cfg.run(name, f);
    report(&r);
    record(&r);
    r
}

/// Black-box to defeat dead-code elimination of benched computations on
/// stable rustc.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports_sane_numbers() {
        let cfg = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 10_000,
            units_per_iter: Some(100.0),
        };
        let mut acc = 0u64;
        let r = cfg.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns + 1.0);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns + 1.0);
        assert!(r.throughput_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn summary_file_roundtrips_via_json() {
        let cfg = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            min_iters: 3,
            max_iters: 100,
            units_per_iter: Some(10.0),
        };
        let r = cfg.run("unit/spin", || {
            black_box(7u64.wrapping_mul(13));
        });
        let j = r.to_json();
        assert_eq!(j.req_str("name").unwrap(), "unit/spin");
        assert!(j.get("throughput_per_sec").is_some());
        record(&r);
        let dir = std::env::temp_dir();
        let path = write_summary(&dir, "dstack_unit_test").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.req_str("bench").unwrap(), "dstack_unit_test");
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert!(
            results.iter().any(|r| r.req_str("name").unwrap() == "unit/spin"),
            "recorded result missing from summary"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sample_sort_total_cmp() {
        // Regression for the partial_cmp().unwrap() this sort used:
        // ascending total_cmp matches partial_cmp on finite samples and
        // places NaN last (greatest) instead of panicking.
        let mut v = vec![3.0f64, 1.0, f64::NAN, 2.0];
        v.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(&v[..3], &[1.0, 2.0, 3.0]);
        assert!(v[3].is_nan());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
