//! Golden-trace regression tests: fixed seed, 2 s horizon, one golden
//! JSON per scheduling policy (single-GPU `RunReport`) and per cluster
//! configuration (`ClusterReport`), diffed against `tests/golden/*.json`
//! with a float tolerance. This is the backbone for perf-refactor PRs:
//! any behavioral drift in the simulator, schedulers, placement or
//! routing shows up as a golden diff.
//!
//! Blessing: a missing golden is written on first run (and reported so
//! it gets committed); `DSTACK_BLESS=1 cargo test` rewrites all of them
//! after an *intentional* behavior change.
//!
//! Tolerances: counters (served/dropped/batches…) are integers and
//! compare exactly; derived floats (utilization, latency percentiles,
//! rates) use a relative tolerance of 1e-6 — large enough for libm-level
//! noise in `ln`/`cos` on exotic platforms, far too small to mask a real
//! scheduling change. See `Json::approx_eq`.

use dstack::cluster::{serve_cluster, GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::config::{build_policy, PolicyKind};
use dstack::profile::{by_name, ModelProfile, T4, V100};
use dstack::sim::{entries_at_optimum, Sim, SimConfig};
use dstack::util::json::Json;
use dstack::workload::{merged_stream, Arrivals};
use std::path::PathBuf;

const TOL: f64 = 1e-6;
const HORIZON_MS: f64 = 2_000.0;
const SEED: u64 = 20_260_731;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(format!("{name}.json"))
}

/// Diff `got` against the stored golden; bless it when absent or when
/// `DSTACK_BLESS` is set.
fn check_golden(name: &str, got: &Json) {
    let path = golden_path(name);
    let bless = std::env::var_os("DSTACK_BLESS").is_some();
    if bless || !path.exists() {
        dstack::util::write_file(&path, &got.to_string_pretty()).unwrap();
        eprintln!("golden '{name}': blessed at {} — commit this file", path.display());
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let want = Json::parse(&text)
        .unwrap_or_else(|e| panic!("golden '{name}' is not valid JSON: {e}"));
    assert!(
        got.approx_eq(&want, TOL),
        "golden '{name}' drifted (rerun with DSTACK_BLESS=1 if intentional)\n\
         --- got ---\n{}\n--- want ---\n{}",
        got.to_string_pretty(),
        want.to_string_pretty()
    );
}

fn c4() -> (Vec<ModelProfile>, Vec<f64>) {
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let rates = vec![700.0, 700.0, 320.0, 160.0];
    (profiles, rates)
}

#[test]
fn single_gpu_run_reports_match_goldens() {
    let (profiles, rates) = c4();
    let entries = entries_at_optimum(&profiles);
    let specs: Vec<_> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, HORIZON_MS, SEED);
    for kind in [
        PolicyKind::Dstack,
        PolicyKind::Temporal,
        PolicyKind::Triton,
        PolicyKind::Gslice,
    ] {
        let mut pol = build_policy(kind, &entries);
        let cfg = SimConfig { horizon_ms: HORIZON_MS, ..Default::default() };
        let mut sim = Sim::new(cfg, entries.clone());
        let rep = sim.run(pol.as_mut(), &reqs);
        check_golden(&format!("run_{}", kind.name()), &rep.to_json());
    }
}

#[test]
fn cluster_reports_match_goldens() {
    let (profiles, rates) = c4();
    let specs: Vec<_> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, HORIZON_MS, SEED);
    let gpus = [V100.clone(), T4.clone(), T4.clone()];
    for (placement, routing) in [
        (PlacementPolicy::FirstFitDecreasing, RoutingPolicy::RoundRobin),
        (PlacementPolicy::FirstFitDecreasing, RoutingPolicy::JoinShortestQueue),
        (PlacementPolicy::LoadBalance, RoutingPolicy::PowerOfTwoChoices),
    ] {
        let rep = serve_cluster(
            &profiles,
            &rates,
            &gpus,
            placement,
            routing,
            GpuSched::Dstack,
            reqs.clone(),
            HORIZON_MS,
            SEED,
        );
        check_golden(
            &format!("cluster_{}_{}", placement.name(), routing.name()),
            &rep.to_json(),
        );
    }
}

#[test]
fn adaptive_cluster_report_matches_golden() {
    // Drifting trace at a 2 s horizon (drift at 1 s): the run includes a
    // detector firing and an applied migration, so estimator, rebalancer
    // and the migration path are all pinned by the golden.
    use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive, AdaptiveCfg};
    let (profiles, initial, _peak, reqs) = drift_workload(HORIZON_MS, SEED);
    let cfg = AdaptiveCfg { interval_ms: 250.0, ..Default::default() };
    let rep = run_adaptive(
        &profiles,
        &initial,
        &drift_gpus(),
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        HORIZON_MS,
        SEED,
    );
    assert!(rep.adaptive.is_some(), "adaptive stats must be serialized");
    check_golden("adaptive_drift", &rep.to_json());
}

#[test]
fn lifecycle_longtail_report_matches_golden() {
    // A memory-oversubscribed 12-model Zipf fleet at a 2 s horizon: the
    // run includes preloads, cold starts, evictions and scale-to-zero,
    // so the store, the residency plan and the warm-routing costs are
    // all pinned by the golden.
    use dstack::lifecycle::{longtail_gpus, longtail_workload, serve_longtail, LifecycleCfg};
    let (profiles, rates, reqs) = longtail_workload(12, 1.1, 400.0, HORIZON_MS, SEED);
    let cfg = LifecycleCfg { mem_budget_mib: 3_072, idle_timeout_ms: 800.0, ..Default::default() };
    let rep = serve_longtail(
        &profiles,
        &rates,
        &longtail_gpus(),
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        HORIZON_MS,
        SEED,
    );
    assert!(rep.lifecycle.is_some(), "lifecycle stats must be serialized");
    check_golden("lifecycle_longtail", &rep.to_json());
}

#[test]
fn unified_drift_pressure_report_matches_golden() {
    // The merged control plane on a rotating-popularity, memory-pressured
    // Zipf fleet at a 2 s horizon: drift replans, footprint-priced
    // replica surgery, cold starts and evictions all land inside the
    // window, so the unified driver's tick loop, residency-biased
    // replanner and cold-migration pricing are all pinned by the golden.
    use dstack::lifecycle::LifecycleCfg;
    use dstack::unified::{drifting_longtail_workload, run_unified, unified_gpus, UnifiedCfg};
    let (profiles, rates, reqs) = drifting_longtail_workload(12, 1.1, 450.0, HORIZON_MS, SEED);
    let cfg = UnifiedCfg {
        lifecycle: LifecycleCfg { mem_budget_mib: 3_072, min_replicas: 1, ..Default::default() },
        ..Default::default()
    };
    let rep = run_unified(
        &profiles,
        &rates,
        &unified_gpus(4),
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        HORIZON_MS,
        SEED,
    );
    assert!(rep.adaptive.is_some(), "adaptive stats must be serialized");
    assert!(rep.lifecycle.is_some(), "lifecycle stats must be serialized");
    assert!(
        rep.adaptive.as_ref().unwrap().cold_migration_ms.is_some(),
        "unified runs must price migrations by cold-load footprint"
    );
    check_golden("unified_drift_pressure", &rep.to_json());
}

#[test]
fn trace_replay_report_matches_golden() {
    // The shipped trace-replay scenario end to end: scenario file →
    // trace loader (reject policy, relative path resolution) → lazy
    // `TraceStream` → streaming cluster core. A golden here pins the
    // whole ingestion pipeline, not just the drivers — any drift in
    // CSV parsing, request expansion or stream merge shows up as a
    // report diff.
    let cfg = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/cluster_trace_replay.json");
    let sc = dstack::config::Scenario::from_file(&cfg).expect("shipped config must load");
    let rep = dstack::config::run_trace_scenario(&sc).expect("shipped trace must replay");
    let total: u64 = rep.served.iter().sum::<u64>() + rep.dropped.iter().sum::<u64>();
    assert!(total > 1_000, "shipped trace should carry a real workload, got {total} requests");
    check_golden("trace_replay", &rep.to_json());
}

#[test]
fn engine_failure_report_matches_golden() {
    // The shipped fault scenario end to end: scenario file → validated
    // fault timeline → streaming cluster core with the resilient front
    // door armed (deadline admission, SLO classes, hedging). The golden
    // pins the whole robustness layer — health machine, drain cascade,
    // cold restore, hedge accounting and the serialized `resilience`
    // block — against behavioral drift.
    let cfg =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/cluster_engine_failure.json");
    let sc = dstack::config::Scenario::from_file(&cfg).expect("shipped config must load");
    let rep = dstack::config::run_cluster_scenario(&sc);
    let res = rep.resilience.as_ref().expect("fault runs must serialize resilience stats");
    assert!(res.engine_downs >= 1, "the shipped timeline must take an engine down");
    check_golden("engine_failure", &rep.to_json());
}

#[test]
fn brownout_flash_report_matches_golden() {
    // The shipped overload scenario end to end: scenario file → variant
    // expansion (primaries + int8 brownouts) → streaming cluster core
    // with retries, breakers and brownout armed. The golden pins the
    // whole overload layer — backoff schedule, breaker state machine,
    // variant co-location, degraded-goodput accounting and the
    // serialized `overload` block — against behavioral drift.
    let cfg =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/cluster_brownout_flash.json");
    let sc = dstack::config::Scenario::from_file(&cfg).expect("shipped config must load");
    let rep = dstack::config::run_cluster_scenario(&sc);
    let o = rep.overload.as_ref().expect("overload runs must serialize overload stats");
    assert!(
        o.retries_scheduled + o.degraded_served_total() + o.breaker_trips > 0,
        "the shipped flash crowd must exercise the overload layer"
    );
    check_golden("brownout_flash", &rep.to_json());
}

#[test]
fn legacy_fig12_cluster_matches_golden() {
    use dstack::cluster::{fig12_workload, run_cluster, ClusterPolicy};
    let (profiles, _rates, reqs) = fig12_workload(HORIZON_MS, SEED);
    for policy in
        [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll]
    {
        let rep = run_cluster(&profiles, &T4, 4, reqs.clone(), HORIZON_MS, policy);
        check_golden(&format!("fig12_{:?}", policy), &rep.to_json());
    }
}
