//! End-to-end tests of the adaptive control plane
//! (`dstack::controlplane`): on the drifting-rate workload the adaptive
//! plane must strictly out-serve the static peak-rate placement at a no
//! worse SLO miss rate, conserve every request across migrations, never
//! oversubscribe a GPU's knee budget, and produce bit-identical runs
//! (including the rebalance schedule) under a fixed seed.

use dstack::cluster::{serve_cluster, ClusterReport, GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive, AdaptiveCfg};

const HORIZON_MS: f64 = 6_000.0;
const SEED: u64 = 42;

fn acfg() -> AdaptiveCfg {
    AdaptiveCfg { interval_ms: 250.0, ..Default::default() }
}

fn run_adaptive_drift(horizon_ms: f64, seed: u64) -> ClusterReport {
    let (profiles, initial, _peak, reqs) = drift_workload(horizon_ms, seed);
    run_adaptive(
        &profiles,
        &initial,
        &drift_gpus(),
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &acfg(),
        reqs,
        horizon_ms,
        seed,
    )
}

fn run_static_peak(horizon_ms: f64, seed: u64) -> ClusterReport {
    let (profiles, _initial, peak, reqs) = drift_workload(horizon_ms, seed);
    serve_cluster(
        &profiles,
        &peak,
        &drift_gpus(),
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        reqs,
        horizon_ms,
        seed,
    )
}

#[test]
fn adaptive_beats_static_on_drifting_trace() {
    let stat = run_static_peak(HORIZON_MS, SEED);
    let adap = run_adaptive_drift(HORIZON_MS, SEED);

    // The static peak-rate packing cannot admit the whole mix (peaks
    // never coincide, but it must provision as if they did).
    let static_rejected = stat.admitted.iter().filter(|&&a| !a).count();
    assert!(static_rejected >= 1, "static admitted everything: {:?}", stat.admitted);

    // The adaptive plane ends with every model placed...
    assert!(adap.admitted.iter().all(|&a| a), "adaptive admitted: {:?}", adap.admitted);
    // ...rebalanced at least once after the drift...
    let stats = adap.adaptive.as_ref().expect("adaptive stats");
    assert!(stats.replans >= 1, "drift never detected");
    assert!(stats.rebalances >= 1, "no rebalance applied");
    assert!(stats.replicas_added >= 1 && stats.replicas_removed >= 1, "{stats:?}");
    for &t in &stats.rebalance_times_us {
        assert!(t > (HORIZON_MS / 2.0 * 1_000.0) as u64, "rebalance before the drift at {t}");
    }

    // ...and strictly out-serves static at a no worse SLO miss rate —
    // the acceptance criterion for the control plane.
    let (s, a) = (stat.total_throughput(), adap.total_throughput());
    assert!(a > s, "adaptive {a:.0} req/s vs static {s:.0} req/s");
    let (sv, av) = (
        stat.violations_per_sec.iter().sum::<f64>(),
        adap.violations_per_sec.iter().sum::<f64>(),
    );
    assert!(av <= sv, "adaptive viol/s {av:.0} vs static {sv:.0}");
}

#[test]
fn adaptive_conserves_requests_across_migrations() {
    let (_profiles, _initial, _peak, reqs) = drift_workload(HORIZON_MS, SEED);
    let rep = run_adaptive_drift(HORIZON_MS, SEED);
    let mut offered = vec![0u64; 4];
    for r in &reqs {
        offered[r.model] += 1;
    }
    for m in 0..4 {
        assert_eq!(
            rep.served[m] + rep.dropped[m] + rep.rejected[m],
            offered[m],
            "model {m}: conservation across rebalances"
        );
        assert!(rep.served[m] > 0, "model {m} starved");
    }
}

#[test]
fn adaptive_never_oversubscribes_knee_budget() {
    // The driver asserts the invariant at every applied delta (removals
    // first, additions bounded by 100%); the final report must also
    // carry a legal packing.
    let rep = run_adaptive_drift(HORIZON_MS, SEED);
    for (g, gr) in rep.per_gpu.iter().enumerate() {
        assert!(gr.knee_load_pct <= 100, "gpu {g} at {}%", gr.knee_load_pct);
    }
    // Utilization stays a valid fraction on every GPU.
    for u in &rep.gpu_utilization {
        assert!((0.0..=1.0).contains(u), "utilization {u}");
    }
}

#[test]
fn identical_seeds_give_identical_rebalance_schedules() {
    let a = run_adaptive_drift(3_000.0, 7);
    let b = run_adaptive_drift(3_000.0, 7);
    assert_eq!(
        a.to_json().to_string_compact(),
        b.to_json().to_string_compact(),
        "same seed must reproduce the full report"
    );
    let (sa, sb) = (a.adaptive.unwrap(), b.adaptive.unwrap());
    assert_eq!(sa.rebalance_times_us, sb.rebalance_times_us);
    assert_eq!(sa.replicas_added, sb.replicas_added);
    assert_eq!(sa.replicas_removed, sb.replicas_removed);
}

#[test]
fn p99_split_reports_both_phases() {
    let rep = run_adaptive_drift(HORIZON_MS, SEED);
    let stats = rep.adaptive.as_ref().unwrap();
    assert_eq!(stats.p99_before_ms.len(), 4);
    assert_eq!(stats.p99_after_ms.len(), 4);
    // With at least one applied rebalance both windows hold completions
    // for the steady background models.
    assert!(stats.rebalances >= 1);
    for m in 2..4 {
        assert!(stats.p99_before_ms[m] > 0.0, "model {m} before-p99 empty");
        assert!(stats.p99_after_ms[m] > 0.0, "model {m} after-p99 empty");
    }
    // Estimates tracked the drift: resnet50 cooled down, vgg19 heated up.
    assert!(stats.est_rates[0] < 900.0, "resnet50 est {:?}", stats.est_rates);
    assert!(stats.est_rates[1] > 100.0, "vgg19 est {:?}", stats.est_rates);
}

#[test]
fn adaptive_without_drift_stays_quiet() {
    // A flat workload (no trace drift) must never fire the detector:
    // the adaptive path then behaves like the static t=0 placement.
    use dstack::profile::by_name;
    use dstack::workload::{merged_stream, Arrivals};
    let profiles = vec![by_name("resnet50").unwrap(), by_name("alexnet").unwrap()];
    let rates = [400.0, 300.0];
    let specs: Vec<_> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, 3_000.0, 11);
    let gpus = drift_gpus();
    let adap = run_adaptive(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &acfg(),
        reqs.clone(),
        3_000.0,
        11,
    );
    let stats = adap.adaptive.as_ref().unwrap();
    assert_eq!(stats.rebalances, 0, "rebalanced a steady workload: {stats:?}");
    // And it matches the static engine's outcome on the same placement
    // inputs: everything admitted and served.
    let stat = serve_cluster(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        reqs,
        3_000.0,
        11,
    );
    assert!(adap.total_throughput() >= 0.95 * stat.total_throughput());
}

#[test]
fn fig13_reports_adaptive_advantage() {
    let d = dstack::figures::fig13();
    assert_eq!(d.rows.len(), 3);
    let total = |label: &str| -> f64 {
        d.rows
            .iter()
            .find(|r| r[0].contains(label))
            .map(|r| r[1].parse().unwrap())
            .unwrap()
    };
    assert!(
        total("adaptive") > total("static (peak"),
        "fig13: adaptive {} vs static-peak {}",
        total("adaptive"),
        total("static (peak")
    );
    let adaptive_row = d.rows.iter().find(|r| r[0] == "adaptive").unwrap();
    let rebalances: u64 = adaptive_row.last().unwrap().parse().unwrap();
    assert!(rebalances >= 1, "fig13 adaptive row saw no rebalances");
}
