//! Scenario config files under configs/ parse, validate and run.

use dstack::config::{run_scenario, PolicyKind, Scenario};
use std::path::Path;

#[test]
fn shipped_configs_parse_and_run() {
    let dir = Path::new("configs");
    let mut found = 0;
    for entry in std::fs::read_dir(dir).expect("configs/ missing") {
        let path = entry.unwrap().path();
        if path.extension().is_some_and(|e| e == "json") {
            let mut sc = Scenario::from_file(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            sc.horizon_ms = sc.horizon_ms.min(1_000.0); // keep tests fast
            let rep = run_scenario(&sc);
            assert_eq!(rep.per_model.len(), sc.models.len(), "{}", path.display());
            found += 1;
        }
    }
    assert!(found >= 3, "expected ≥3 shipped scenario configs, found {found}");
}

#[test]
fn roundtrip_preserves_semantics() {
    let sc = Scenario::from_file(Path::new("configs/c4_dstack.json")).unwrap();
    let text = sc.to_json().to_string_pretty();
    let sc2 = Scenario::from_json(&text).unwrap();
    assert_eq!(sc.policy, sc2.policy);
    assert_eq!(sc.models.len(), sc2.models.len());
    for (a, b) in sc.models.iter().zip(&sc2.models) {
        assert_eq!(a.name, b.name);
        assert!((a.rate - b.rate).abs() < 1e-9);
    }
}

#[test]
fn policy_parse_covers_all() {
    for k in PolicyKind::all() {
        assert_eq!(PolicyKind::parse(k.name()).unwrap(), *k);
    }
    assert!(PolicyKind::parse("bogus").is_err());
}
