//! Property-based scheduler invariants (mini harness, see util::prop):
//! random model mixes, rates and seeds; the system-level invariants of
//! §6 must hold on every run.

use dstack::config::{build_policy, PolicyKind};
use dstack::prop_assert;
use dstack::sim::{entries_at_optimum, Sim, SimConfig};
use dstack::util::prop::Cases;
use dstack::workload::{merged_stream, Arrivals};

const ZOO: &[&str] =
    &["mobilenet", "alexnet", "bert", "resnet50", "vgg19", "resnet18", "inception", "resnext50"];

fn random_mix(g: &mut dstack::util::prop::Gen) -> (Vec<&'static str>, Vec<f64>, u64) {
    let names = g.subset(ZOO, 2);
    let rates: Vec<f64> = (0..names.len()).map(|_| g.f64_in(50.0, 800.0)).collect();
    (names, rates, g.u64())
}

fn run(
    names: &[&str],
    rates: &[f64],
    kind: PolicyKind,
    seed: u64,
    gantt: bool,
) -> (dstack::metrics::RunReport, Sim) {
    let profiles: Vec<_> =
        names.iter().map(|n| dstack::profile::by_name(n).unwrap()).collect();
    let entries = entries_at_optimum(&profiles);
    let specs: Vec<_> = profiles
        .iter()
        .zip(rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, 2_000.0, seed);
    let mut pol = build_policy(kind, &entries);
    let cfg = SimConfig {
        horizon_ms: 2_000.0,
        gantt,
        allow_oversub: kind == PolicyKind::FixedBatch,
        ..Default::default()
    };
    let mut sim = Sim::new(cfg, entries);
    let rep = sim.run(pol.as_mut(), &reqs);
    (rep, sim)
}

#[test]
fn never_oversubscribed_and_requests_conserved() {
    // The GpuSim panics on oversubscription for controlled policies, so
    // completing a run IS the invariant check; conservation on top.
    let kinds = [
        PolicyKind::Dstack,
        PolicyKind::SpatioTemporalOnly,
        PolicyKind::Temporal,
        PolicyKind::Gslice,
        PolicyKind::Triton,
        PolicyKind::MaxThroughput,
        PolicyKind::MaxMin,
    ];
    Cases::new(24).seed(0xA11CE).run(|g| {
        let (names, rates, seed) = random_mix(g);
        let kind = *g.pick(&kinds);
        let (rep, _) = run(&names, &rates, kind, seed, false);
        let offered: u64 = rep.per_model.iter().map(|m| m.offered()).sum();
        let served: u64 = rep.per_model.iter().map(|m| m.served).sum();
        let dropped: u64 = rep.per_model.iter().map(|m| m.dropped).sum();
        prop_assert!(offered == served + dropped, "{kind:?}: conservation violated");
        prop_assert!(
            rep.mean_utilization() <= 1.0 + 1e-9,
            "{kind:?}: utilization > 1"
        );
        for m in &rep.per_model {
            prop_assert!(
                m.served_in_slo <= m.served,
                "in-SLO exceeds served for {}",
                m.name
            );
        }
        Ok(())
    });
}

#[test]
fn gantt_capacity_invariant() {
    // Reconstruct instantaneous usage from the Gantt log: controlled
    // policies must never exceed 100% at any instant.
    Cases::new(10).seed(0xB0B).run(|g| {
        let (names, rates, seed) = random_mix(g);
        let kind = *g.pick(&[PolicyKind::Dstack, PolicyKind::Gslice, PolicyKind::MaxMin]);
        let (_, sim) = run(&names, &rates, kind, seed, true);
        let gantt = sim.gpu.gantt.as_ref().unwrap();
        let mut events: Vec<(u64, i64)> = Vec::new();
        for e in gantt {
            events.push((e.start, e.pct as i64));
            events.push((e.end, -(e.pct as i64)));
        }
        events.sort();
        let mut level = 0i64;
        for (_, d) in events {
            level += d;
            prop_assert!(level <= 100, "{kind:?}: instantaneous usage {level} > 100");
        }
        Ok(())
    });
}

#[test]
fn temporal_never_overlaps() {
    Cases::new(10).seed(0xC0DE).run(|g| {
        let (names, rates, seed) = random_mix(g);
        let (_, sim) = run(&names, &rates, PolicyKind::Temporal, seed, true);
        let gantt = sim.gpu.gantt.as_ref().unwrap();
        for w in gantt.windows(2) {
            prop_assert!(w[1].start >= w[0].end, "temporal overlap {w:?}");
        }
        Ok(())
    });
}

#[test]
fn latencies_bounded_below_by_service_time() {
    // No request can complete faster than its batch's inference time at
    // 100% GPU — a causality check on the event engine.
    Cases::new(10).seed(0xF00D).run(|g| {
        let (names, rates, seed) = random_mix(g);
        let (rep, _) = run(&names, &rates, PolicyKind::Dstack, seed, false);
        for (m, name) in rep.per_model.iter().zip(&names) {
            let p = dstack::profile::by_name(name).unwrap();
            let min_service = p.latency_ms(100, 1);
            for &l in &m.latencies_ms {
                // µs-granular virtual time rounds durations down by up
                // to 1 µs (0.001 ms); allow that plus float noise.
                prop_assert!(
                    l >= min_service - 2e-3,
                    "{name}: latency {l} < min service {min_service}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn dstack_dominates_temporal_on_throughput() {
    // Across random mixes, D-STACK's total throughput is never
    // meaningfully below temporal sharing's (the paper's headline is a
    // 3-4x win; we assert no regression anywhere).
    Cases::new(12).seed(0xD57).run(|g| {
        let (names, rates, seed) = random_mix(g);
        let (t, _) = run(&names, &rates, PolicyKind::Temporal, seed, false);
        let (d, _) = run(&names, &rates, PolicyKind::Dstack, seed, false);
        prop_assert!(
            d.total_throughput() >= 0.9 * t.total_throughput(),
            "dstack {} < temporal {} on {names:?}",
            d.total_throughput(),
            t.total_throughput()
        );
        Ok(())
    });
}
