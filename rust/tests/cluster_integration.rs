//! Cluster-level integration: placement correctness and determinism.

use dstack::cluster::{entries_for_gpu, run_cluster, ClusterPolicy};
use dstack::profile::{by_name, T4, V100};
use dstack::workload::{merged_stream, Arrivals};

fn setup() -> (Vec<dstack::profile::ModelProfile>, Vec<dstack::workload::Request>) {
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let specs: Vec<_> = profiles
        .iter()
        .map(|p| (Arrivals::Poisson { rate: 400.0 }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, 3_000.0, 4);
    (profiles, reqs)
}

#[test]
fn cluster_runs_deterministic() {
    let (profiles, reqs) = setup();
    let a = run_cluster(&profiles, &T4, 4, reqs.clone(), 3_000.0, ClusterPolicy::DstackAll);
    let b = run_cluster(&profiles, &T4, 4, reqs, 3_000.0, ClusterPolicy::DstackAll);
    assert_eq!(a.throughput, b.throughput);
    assert_eq!(a.gpu_utilization, b.gpu_utilization);
}

#[test]
fn more_gpus_more_throughput_under_overload() {
    let names = ["resnet50", "vgg19"];
    let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let specs: Vec<_> = profiles
        .iter()
        .map(|p| (Arrivals::Poisson { rate: 2_000.0 }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, 3_000.0, 6);
    let two = run_cluster(&profiles, &T4, 2, reqs.clone(), 3_000.0, ClusterPolicy::DstackAll);
    let four = run_cluster(&profiles, &T4, 4, reqs, 3_000.0, ClusterPolicy::DstackAll);
    assert!(
        four.total_throughput() > 1.5 * two.total_throughput(),
        "2 GPUs {} vs 4 GPUs {}",
        two.total_throughput(),
        four.total_throughput()
    );
}

#[test]
fn operating_points_adapt_to_gpu() {
    let profiles = vec![by_name("vgg19").unwrap()];
    let v = entries_for_gpu(&profiles, &V100);
    let t = entries_for_gpu(&profiles, &T4);
    // VGG-19's knee is 40 of 80 SMs on V100; on the 40-SM T4 it wants
    // proportionally more of the device.
    assert!(t[0].pct > v[0].pct, "t4 {} vs v100 {}", t[0].pct, v[0].pct);
}

#[test]
#[should_panic(expected = "exclusive placement")]
fn exclusive_requires_enough_gpus() {
    let (profiles, reqs) = setup();
    run_cluster(&profiles, &T4, 2, reqs, 1_000.0, ClusterPolicy::Exclusive);
}
