//! Robustness tests for the streaming trace loader (DESIGN.md §4.10):
//! hand-computed goldens for both on-disk formats, the sort-or-reject
//! ordering policy, line-numbered errors for malformed / truncated /
//! misaddressed records (never panics), horizon cuts, and the lazy
//! path's O(1) buffering.

use dstack::workload::{
    load_trace, ArrivalStream, Request, TraceSpec, TraceStream, UnsortedPolicy,
};
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data").join(name)
}

fn spec() -> TraceSpec {
    TraceSpec {
        models: vec![
            ("mobilenet".into(), 100.0),
            ("alexnet".into(), 50.0),
            ("resnet50".into(), 25.0),
        ],
        horizon_ms: 100.0,
        policy: UnsortedPolicy::Reject,
    }
}

/// The expansion both valid fixtures encode, computed by hand: the CSV
/// exercises reordered + extra columns and a numeric model index, the
/// JSONL a defaulted `count` and the bare `timestamp` spelling.
fn expected() -> Vec<Request> {
    let rq = |id: u64, model: usize, arrival: u64, slo: u64| Request {
        id,
        model,
        arrival,
        deadline: arrival + slo,
    };
    vec![
        rq(0, 0, 0, 100_000),
        rq(1, 0, 0, 100_000),
        rq(2, 1, 5_000, 50_000),
        rq(3, 2, 12_500, 25_000),
        rq(4, 2, 12_500, 25_000),
        rq(5, 2, 12_500, 25_000),
    ]
}

#[test]
fn valid_traces_match_the_hand_computed_expansion() {
    for name in ["trace_valid.csv", "trace_valid.jsonl"] {
        let path = fixture(name);
        let reqs = load_trace(&path, &spec()).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(reqs, expected(), "{name} diverged from the hand-computed expansion");

        // The streaming interface agrees with the eager adapter: same
        // total, same peeks, O(1) buffering (never more than the
        // current record's count), and conservative per-model peeks
        // equal to the global head.
        let mut s = TraceStream::open(&path, &spec()).unwrap();
        assert_eq!(s.total_requests(), 6);
        let mut drained = Vec::new();
        while let Some(t) = s.peek_time() {
            assert_eq!(s.peek_model(0), Some(t), "lazy peek_model must be the global head");
            assert!(s.buffered() <= 3, "lazy replay buffered a whole trace");
            let r = s.next_request().unwrap();
            assert_eq!(r.arrival, t);
            drained.push(r);
        }
        assert_eq!(drained, expected());
        assert!(s.next_request().is_none());
    }
}

#[test]
fn unsorted_traces_reject_with_the_offending_line_or_sort() {
    let path = fixture("trace_unsorted.csv");
    let err = TraceStream::open(&path, &spec()).unwrap_err();
    assert!(err.contains("out of order"), "unexpected error: {err}");
    assert!(err.contains("trace_unsorted.csv:3"), "error must name file:line, got: {err}");
    assert!(err.contains("\"sort\""), "error must point at the sort policy, got: {err}");

    let sort_spec = TraceSpec { policy: UnsortedPolicy::Sort, ..spec() };
    let sorted = load_trace(&path, &sort_spec).unwrap();
    let arrivals: Vec<(usize, u64)> = sorted.iter().map(|r| (r.model, r.arrival)).collect();
    assert_eq!(arrivals, vec![(1, 4_000), (0, 10_000), (2, 20_000)]);
    for (i, r) in sorted.iter().enumerate() {
        assert_eq!(r.id, i as u64, "sorted replay must reassign ids in arrival order");
    }
}

#[test]
fn malformed_and_truncated_traces_err_with_line_numbers() {
    let err = TraceStream::open(&fixture("trace_malformed.csv"), &spec()).unwrap_err();
    assert!(err.contains("trace_malformed.csv:3"), "error must name file:line, got: {err}");
    assert!(err.contains("bad timestamp"), "unexpected error: {err}");

    // A half-written JSONL line (interrupted writer) is a load error on
    // the exact line, not a panic or a silent partial replay.
    let err = TraceStream::open(&fixture("trace_truncated.jsonl"), &spec()).unwrap_err();
    assert!(err.contains("trace_truncated.jsonl:2"), "error must name file:line, got: {err}");
    assert!(err.contains("bad JSON record"), "unexpected error: {err}");

    // Both policies surface the same validation errors.
    let sort = TraceSpec { policy: UnsortedPolicy::Sort, ..spec() };
    assert!(TraceStream::open(&fixture("trace_malformed.csv"), &sort).is_err());
    assert!(TraceStream::open(&fixture("trace_truncated.jsonl"), &sort).is_err());
}

#[test]
fn misaddressed_models_and_missing_files_err() {
    // Shrink the spec to one model: the valid CSV's numeric index 1 is
    // now out of range — reported with its line number.
    let narrow = TraceSpec { models: vec![("mobilenet".into(), 100.0)], ..spec() };
    let err = TraceStream::open(&fixture("trace_valid.csv"), &narrow).unwrap_err();
    assert!(err.contains("out of range"), "unexpected error: {err}");
    assert!(err.contains("trace_valid.csv:3"), "error must name file:line, got: {err}");

    // Unknown model *name*: swap the spec's names out from under the CSV.
    let renamed = TraceSpec {
        models: vec![("a".into(), 1.0), ("b".into(), 1.0), ("c".into(), 1.0)],
        ..spec()
    };
    let err = TraceStream::open(&fixture("trace_valid.csv"), &renamed).unwrap_err();
    assert!(err.contains("unknown model 'mobilenet'"), "unexpected error: {err}");

    let err = TraceStream::open(&fixture("no_such_trace.csv"), &spec()).unwrap_err();
    assert!(err.contains("cannot open trace"), "unexpected error: {err}");
    let err = TraceStream::open(&fixture("trace_valid.txt"), &spec()).unwrap_err();
    assert!(err.contains("unknown trace format"), "unexpected error: {err}");
}

#[test]
fn horizon_cuts_and_empty_traces() {
    // Records at or past the horizon are dropped — 12.5 ms is out when
    // the horizon is 10 ms — and the validated total reflects the cut.
    let cut = TraceSpec { horizon_ms: 10.0, ..spec() };
    let path = fixture("trace_valid.csv");
    let s = TraceStream::open(&path, &cut).unwrap();
    assert_eq!(s.total_requests(), 3);
    let reqs = load_trace(&path, &cut).unwrap();
    assert_eq!(reqs, expected()[..3].to_vec());
    // A horizon-exact record is excluded (half-open horizon).
    let exact = TraceSpec { horizon_ms: 12.5, ..spec() };
    assert_eq!(load_trace(&path, &exact).unwrap().len(), 3);

    // A header-only trace is an empty, well-behaved stream.
    let mut s = TraceStream::open(&fixture("trace_header_only.csv"), &spec()).unwrap();
    assert_eq!(s.total_requests(), 0);
    assert!(s.peek_time().is_none());
    assert!(s.next_request().is_none());
}
