//! End-to-end PJRT integration: load AOT artifacts compiled by JAX,
//! regenerate weights in Rust, execute on the PJRT CPU client, and
//! verify the numerics match what JAX computed at build time.
//! Requires `make artifacts` (skips with a notice when absent).

use dstack::runtime::{artifacts_dir, iota_input, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = artifacts_dir();
    match Runtime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIPPED (run `make artifacts` first): {e}");
            None
        }
    }
}

#[test]
fn selfcheck_every_artifact() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.load_all_checked().expect("selfcheck failed");
    assert!(n >= 16, "expected ≥16 artifacts, got {n}");
}

#[test]
fn inference_shapes_and_determinism() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let loaded = rt.load("convnet1", 1).unwrap();
    let x = iota_input(&loaded.artifact.input_shape);
    let a = loaded.infer(&x).unwrap();
    let b = loaded.infer(&x).unwrap();
    assert_eq!(a.len(), 10);
    assert_eq!(a, b, "PJRT execution must be deterministic");
}

#[test]
fn batch_row_consistency_across_executables() {
    // Row 0 of the batch-16 executable ≈ the batch-1 executable on the
    // same data (independent HLO lowerings of the same model).
    let Some(mut rt) = runtime_or_skip() else { return };
    rt.load("alexnet_mini", 1).unwrap();
    rt.load("alexnet_mini", 16).unwrap();
    let l16 = rt.get("alexnet_mini", 16).unwrap();
    let x16 = iota_input(&l16.artifact.input_shape);
    let out16 = l16.infer(&x16).unwrap();
    let item = 32 * 32 * 3;
    let l1 = rt.get("alexnet_mini", 1).unwrap();
    let out1 = l1.infer(&x16[..item]).unwrap();
    for (i, (&a, &b)) in out1.iter().zip(out16[..10].iter()).enumerate() {
        assert!((a - b).abs() < 1e-3, "logit {i}: {a} vs {b}");
    }
}

#[test]
fn wrong_input_length_rejected() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let loaded = rt.load("convnet1", 1).unwrap();
    assert!(loaded.infer(&[0.0; 7]).is_err());
}

#[test]
fn missing_artifact_errors() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert!(rt.load("convnet1", 3).is_err());
    assert!(rt.load("unknown_model", 1).is_err());
}
