//! Seeded chaos sweep: randomized combinations of fault timelines,
//! workload shape, eviction policy and retry/breaker/brownout knobs,
//! driven through the static, lifecycle and unified drivers. Every
//! combination must uphold the simulator's global invariants —
//! request conservation, availability ∈ [0, 100], knee load ≤ 100% per
//! GPU at placement time, and epoch/sparse byte-identity. Failures
//! print the per-iteration seed; re-run a single case with
//! `DSTACK_CHAOS_SEED=<seed> DSTACK_CHAOS_ITERS=1 cargo test --test chaos`.

use dstack::cluster::{
    place, serve_cluster_stream_overload, ClusterReport, ExecMode, ExecOpts, GpuSched,
    Parallelism, PlacementPolicy, RoutingPolicy,
};
use dstack::faults::{FaultEvent, FaultKind, ResilienceCfg};
use dstack::gpu::ms_to_us;
use dstack::lifecycle::{
    longtail_gpus, longtail_workload, serve_longtail_stream_overload, EvictionPolicy, LifecycleCfg,
};
use dstack::overload::{expand_profiles, OverloadCfg, OverloadSpec, VariantMap, VariantSpec};
use dstack::profile::{by_name, ModelProfile, T4, V100};
use dstack::unified::{drifting_longtail_workload, run_unified_stream_overload, unified_gpus, UnifiedCfg};
use dstack::workload::{merged_stream, Arrivals, MaterializedStream, Request};

/// SplitMix64: a tiny deterministic generator for deriving case
/// parameters. Not the simulator's RNG — just the fuzzer's dice.
struct Dice(u64);

impl Dice {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `0..n`.
    fn pick(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }

    fn chance(&mut self, pct: u64) -> bool {
        self.pick(100) < pct
    }
}

fn offered_counts(reqs: &[Request], n_models: usize) -> Vec<u64> {
    let mut off = vec![0u64; n_models];
    for r in reqs {
        off[r.model] += 1;
    }
    off
}

fn check_invariants(rep: &ClusterReport, offered: &[u64], label: &str) {
    let off: u64 = offered.iter().sum();
    let acc: u64 = (0..rep.served.len())
        .map(|m| rep.served[m] + rep.dropped[m] + rep.rejected[m])
        .sum();
    assert_eq!(acc, off, "{label}: conservation violated");
    if let Some(res) = &rep.resilience {
        assert!(
            (0.0..=100.0).contains(&res.availability_pct),
            "{label}: availability {} out of [0, 100]",
            res.availability_pct
        );
    }
    if let Some(o) = &rep.overload {
        assert!(o.retries_succeeded <= o.retries_scheduled, "{label}: {o:?}");
        assert!(o.breaker_probes <= o.breaker_trips, "{label}: more probes than trips: {o:?}");
    }
}

/// A random but *valid* fault timeline: per GPU at most one
/// degraded→down→up prefix, truncated at a random depth.
fn random_faults(d: &mut Dice, n_gpus: usize, horizon_ms: f64) -> Option<ResilienceCfg> {
    if d.chance(25) {
        return None; // no fault layer at all
    }
    let mut events = Vec::new();
    for g in 0..n_gpus {
        if !d.chance(50) {
            continue;
        }
        let t0 = 100.0 + d.pick((horizon_ms * 0.4) as u64) as f64;
        let script: &[FaultKind] = match d.pick(3) {
            0 => &[FaultKind::Degraded],
            1 => &[FaultKind::Down, FaultKind::Up],
            _ => &[FaultKind::Degraded, FaultKind::Down, FaultKind::Up],
        };
        let depth = 1 + d.pick(script.len() as u64) as usize;
        for (i, kind) in script[..depth].iter().enumerate() {
            events.push(FaultEvent {
                t: ms_to_us(t0 + i as f64 * (50.0 + d.pick(300) as f64)),
                gpu: g,
                kind: *kind,
            });
        }
    }
    Some(ResilienceCfg {
        events,
        bulk_models: if d.chance(50) { vec!["vgg19".into()] } else { Vec::new() },
        admission: true,
        hedge: d.chance(30),
        ..Default::default()
    })
}

fn random_overload(d: &mut Dice, map: VariantMap) -> OverloadSpec {
    OverloadSpec {
        cfg: OverloadCfg {
            max_retries: d.pick(4) as u32,
            backoff_base_ms: 2.0 + d.pick(20) as f64,
            backoff_cap_ms: 200.0,
            breaker_k: d.pick(9) as u32,
            breaker_window_ms: 200.0 + d.pick(400) as f64,
            breaker_cooldown_ms: 50.0 + d.pick(300) as f64,
            brownout: d.chance(70),
            ..Default::default()
        },
        map,
    }
}

fn epoch1() -> ExecOpts {
    ExecOpts { threads: Parallelism::Threads(1), mode: ExecMode::Epoch, ..Default::default() }
}

fn sparse_n(threads: usize) -> ExecOpts {
    ExecOpts { threads: Parallelism::Threads(threads), mode: ExecMode::Sparse, ..Default::default() }
}

/// One static-driver case: random zoo subset, optional variant
/// expansion, random faults + overload knobs.
fn static_case(seed: u64) -> (String, String) {
    let mut d = Dice(seed);
    let zoo = ["mobilenet", "alexnet", "resnet50", "vgg19", "resnet18"];
    let n = 2 + d.pick(3) as usize;
    let base: Vec<ModelProfile> = zoo[..n].iter().map(|s| by_name(s).unwrap()).collect();
    let decls: Vec<(usize, VariantSpec)> = if d.chance(60) {
        vec![(
            d.pick(n as u64) as usize,
            VariantSpec {
                name: "chaos_variant".into(),
                knee_pct: 10 + d.pick(20) as u32,
                latency_scale: 0.4 + d.pick(5) as f64 / 10.0,
                mem_mib: 200 + d.pick(400),
            },
        )]
    } else {
        Vec::new()
    };
    let (profiles, map) = expand_profiles(&base, &decls).expect("valid chaos variant");
    let horizon_ms = 1_200.0 + d.pick(1_000) as f64;
    let specs: Vec<_> = base
        .iter()
        .map(|p| {
            let rate = 80.0 + d.pick(400) as f64;
            if d.chance(30) {
                (
                    Arrivals::Flash {
                        base: rate,
                        mult: 2.0 + d.pick(4) as f64,
                        spike_start_ms: horizon_ms * 0.3,
                        spike_ms: horizon_ms * 0.3,
                    },
                    p.slo_ms,
                )
            } else {
                (Arrivals::Poisson { rate }, p.slo_ms)
            }
        })
        .collect();
    let reqs = merged_stream(&specs, horizon_ms, seed);
    let offered = offered_counts(&reqs, profiles.len());
    let mut rates: Vec<f64> = specs
        .iter()
        .map(|(a, _)| match a {
            Arrivals::Poisson { rate } => *rate,
            Arrivals::Flash { base, .. } => *base,
            _ => 100.0,
        })
        .collect();
    rates.resize(profiles.len(), 0.0);
    let gpus: Vec<_> = match d.pick(3) {
        0 => vec![V100.clone(), T4.clone()],
        1 => vec![T4.clone(), T4.clone()],
        _ => vec![V100.clone(), T4.clone(), T4.clone()],
    };
    // Knee invariant at placement time: the packer may never
    // oversubscribe a GPU's spatial budget.
    let pl = place(&profiles[..map.n_primary], &rates[..map.n_primary], &gpus, PlacementPolicy::LoadBalance);
    for (g, &k) in pl.knee_load.iter().enumerate() {
        assert!(k <= 100, "case {seed}: GPU {g} packed past 100% knee ({k})");
    }
    let faults = random_faults(&mut d, gpus.len(), horizon_ms);
    let ovl = random_overload(&mut d, map);
    let run = |opts: ExecOpts| {
        serve_cluster_stream_overload(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            horizon_ms,
            seed,
            opts,
            faults.as_ref(),
            Some(&ovl),
        )
    };
    let a = run(epoch1());
    check_invariants(&a, &offered, &format!("static case {seed}"));
    (a.to_json().to_string_pretty(), run(sparse_n(4)).to_json().to_string_pretty())
}

/// One lifecycle-driver case: memory pressure + random eviction policy
/// under faults and overload.
fn lifecycle_case(seed: u64) -> (String, String) {
    let mut d = Dice(seed);
    let n_models = 8 + d.pick(6) as usize;
    let rate = 300.0 + d.pick(250) as f64;
    let horizon_ms = 1_500.0 + d.pick(800) as f64;
    let (profiles, rates, reqs) = longtail_workload(n_models, 1.1, rate, horizon_ms, seed);
    let offered = offered_counts(&reqs, profiles.len());
    let eviction = match d.pick(3) {
        0 => EvictionPolicy::Lru,
        1 => EvictionPolicy::Lfu,
        _ => EvictionPolicy::CostAware,
    };
    let lcfg = LifecycleCfg {
        eviction,
        mem_budget_mib: 1_536 + d.pick(2_048),
        idle_timeout_ms: if d.chance(50) { 300.0 } else { 0.0 },
        ..Default::default()
    };
    let gpus = longtail_gpus();
    let faults = random_faults(&mut d, gpus.len(), horizon_ms);
    let ovl = random_overload(&mut d, VariantMap::trivial(profiles.len()));
    let run = |opts: ExecOpts| {
        serve_longtail_stream_overload(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &lcfg,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            horizon_ms,
            seed,
            opts,
            faults.as_ref(),
            Some(&ovl),
        )
    };
    let a = run(epoch1());
    check_invariants(&a, &offered, &format!("lifecycle case {seed}"));
    (a.to_json().to_string_pretty(), run(sparse_n(2)).to_json().to_string_pretty())
}

/// One unified-driver case: drift + residency churn under overload.
fn unified_case(seed: u64) -> (String, String) {
    let mut d = Dice(seed);
    let n_models = 10 + d.pick(4) as usize;
    let rate = 350.0 + d.pick(200) as f64;
    let horizon_ms = 1_500.0 + d.pick(700) as f64;
    let (profiles, rates, reqs) =
        drifting_longtail_workload(n_models, 1.1, rate, horizon_ms, seed);
    let offered = offered_counts(&reqs, profiles.len());
    let ucfg = UnifiedCfg {
        lifecycle: LifecycleCfg {
            mem_budget_mib: 2_048 + d.pick(2_048),
            min_replicas: 1 + d.pick(2) as usize,
            ..Default::default()
        },
        ..Default::default()
    };
    let gpus = unified_gpus(3 + d.pick(2) as usize);
    let faults = random_faults(&mut d, gpus.len(), horizon_ms);
    let ovl = random_overload(&mut d, VariantMap::trivial(profiles.len()));
    let run = |opts: ExecOpts| {
        run_unified_stream_overload(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &ucfg,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            horizon_ms,
            seed,
            opts,
            faults.as_ref(),
            Some(&ovl),
        )
    };
    let a = run(epoch1());
    check_invariants(&a, &offered, &format!("unified case {seed}"));
    (a.to_json().to_string_pretty(), run(sparse_n(4)).to_json().to_string_pretty())
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[test]
fn chaos_sweep_upholds_invariants() {
    let base_seed = env_u64("DSTACK_CHAOS_SEED", 0xD57A);
    let iters = env_u64("DSTACK_CHAOS_ITERS", 9);
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x1_0000));
        // Rotate drivers so every run covers all three; a single
        // failing (driver, seed) pair reproduces via DSTACK_CHAOS_SEED
        // with DSTACK_CHAOS_ITERS=1 after adding the offset printed in
        // the panic label.
        let (epoch, sparse) = match i % 3 {
            0 => static_case(seed),
            1 => lifecycle_case(seed),
            _ => unified_case(seed),
        };
        assert_eq!(
            epoch, sparse,
            "chaos case seed={seed} (iter {i}): epoch and sparse reports diverged"
        );
    }
}
