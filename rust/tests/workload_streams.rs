//! Property tests for the arrival generators and the lazy merge layer
//! (DESIGN.md §4.10): statistical sanity for the bursty processes
//! (MMPP / diurnal / flash), determinism per seed, global ordering with
//! model-index tie-breaks, and the boundary cases the execution core
//! leans on (empty streams, single arrivals, horizon-exact exclusion).

use dstack::util::rng::Pcg32;
use dstack::workload::{
    bursty_arrivals, merged_stream, ArrivalStream, Arrivals, MergedStream, Request,
};

/// Collect a process's arrivals over `[0, horizon_ms)` for model 0.
fn collect(arr: &Arrivals, horizon_ms: f64, seed: u64) -> Vec<Request> {
    arr.iter(0, 100.0, horizon_ms, Pcg32::new(seed, 1)).collect()
}

/// Count arrivals in `[lo_ms, hi_ms)`.
fn count_in(reqs: &[Request], lo_ms: f64, hi_ms: f64) -> usize {
    let (lo, hi) = ((lo_ms * 1_000.0) as u64, (hi_ms * 1_000.0) as u64);
    reqs.iter().filter(|r| r.arrival >= lo && r.arrival < hi).count()
}

#[test]
fn mmpp_empirical_rate_matches_stationary_mean() {
    let arr = Arrivals::Mmpp {
        rate_low: 50.0,
        rate_high: 200.0,
        dwell_low_ms: 400.0,
        dwell_high_ms: 200.0,
    };
    // (50·400 + 200·200) / 600 = 100 req/s — the figure `rate_at`
    // reports at every t (modulation state is random, so "rate at t"
    // is the stationary mean) and placement sizing budgets for.
    assert!((arr.rate_at(0.0) - 100.0).abs() < 1e-9);
    assert!((arr.rate_at(12_345.6) - 100.0).abs() < 1e-9);
    assert_eq!(arr.peak_rate(), 200.0);
    // Long-horizon empirical rate converges to that mean: 200 s spans
    // ~330 dwell cycles, so ±10% is a loose bound.
    let horizon_s = 200.0;
    let n = collect(&arr, horizon_s * 1_000.0, 7).len() as f64;
    let empirical = n / horizon_s;
    assert!(
        (empirical - 100.0).abs() < 10.0,
        "MMPP empirical rate {empirical:.1}/s strayed from the stationary mean 100/s"
    );
}

#[test]
fn generators_are_ordered_deterministic_and_horizon_bounded() {
    let horizon_ms = 5_000.0;
    let shapes = [
        bursty_arrivals("poisson", 120.0, horizon_ms).unwrap(),
        bursty_arrivals("mmpp", 120.0, horizon_ms).unwrap(),
        bursty_arrivals("diurnal", 120.0, horizon_ms).unwrap(),
        bursty_arrivals("flash", 120.0, horizon_ms).unwrap(),
    ];
    for arr in &shapes {
        let a = collect(arr, horizon_ms, 42);
        assert!(a.len() > 100, "{arr:?} produced only {} arrivals", a.len());
        // Nondecreasing, strictly inside [0, horizon), deadline = arrival + SLO.
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "{arr:?} emitted out of order");
        }
        for r in &a {
            assert!(r.arrival < (horizon_ms * 1_000.0) as u64, "{arr:?} escaped the horizon");
            assert_eq!(r.deadline, r.arrival + 100_000, "deadline must be arrival + SLO");
        }
        // Same seed → byte-identical stream; fresh seed → a different one.
        assert_eq!(a, collect(arr, horizon_ms, 42), "{arr:?} is not deterministic per seed");
        if !matches!(arr, Arrivals::Uniform { .. }) {
            assert_ne!(a, collect(arr, horizon_ms, 43), "{arr:?} ignored its seed");
        }
    }
    assert!(bursty_arrivals("sawtooth", 120.0, horizon_ms).is_err(), "unknown kind must err");
}

#[test]
fn flash_spike_concentrates_arrivals() {
    // 6× spike over [400, 500) ms against a 50/s base: the spike window
    // must clearly dominate every quiet window of the same width.
    let arr = Arrivals::Flash { base: 50.0, mult: 6.0, spike_start_ms: 400.0, spike_ms: 100.0 };
    let a = collect(&arr, 1_000.0, 11);
    let spike = count_in(&a, 400.0, 500.0);
    let quiet_max = (0..10)
        .filter(|&k| k != 4)
        .map(|k| count_in(&a, k as f64 * 100.0, (k + 1) as f64 * 100.0))
        .max()
        .unwrap();
    assert!(
        spike > 2 * quiet_max,
        "spike window held {spike} arrivals vs quiet max {quiet_max} — no burst visible"
    );
}

#[test]
fn diurnal_counts_follow_the_sine() {
    // rate(t) = 100 + 80·sin(2πt/1000): crest near t ≡ 250, trough near
    // t ≡ 750. Summed over 10 periods the contrast is unmistakable.
    let arr = Arrivals::Diurnal { base: 100.0, amplitude: 80.0, period_ms: 1_000.0, phase: 0.0 };
    let a = collect(&arr, 10_000.0, 5);
    let (mut crest, mut trough) = (0, 0);
    for k in 0..10 {
        let t0 = k as f64 * 1_000.0;
        crest += count_in(&a, t0 + 200.0, t0 + 300.0);
        trough += count_in(&a, t0 + 700.0, t0 + 800.0);
    }
    assert!(
        crest > 3 * trough.max(1),
        "crest windows held {crest} arrivals vs trough {trough} — no modulation visible"
    );
}

#[test]
fn merged_stream_orders_ties_by_model_index() {
    // Two zero-jitter uniform processes at the same rate arrive at the
    // exact same instants (gap = 1000/rate regardless of seed), so the
    // merge must break every tie by model index, with merge-order ids.
    let specs = vec![(Arrivals::Uniform { rate: 10.0, jitter: 0.0 }, 50.0); 2];
    let merged: Vec<Request> = MergedStream::new(&specs, 1_000.0, 3).collect();
    assert_eq!(merged.len(), 18, "9 deterministic arrivals per model");
    for (i, pair) in merged.chunks(2).enumerate() {
        let expect = ((i + 1) as u64) * 100_000;
        assert_eq!(pair[0].arrival, expect);
        assert_eq!(pair[1].arrival, expect);
        assert_eq!((pair[0].model, pair[1].model), (0, 1), "tie not broken by model index");
    }
    for (i, r) in merged.iter().enumerate() {
        assert_eq!(r.id, i as u64, "ids must be dense in merge order");
    }
    // And with heterogeneous processes the global order still holds and
    // matches the eager adapter request for request.
    let specs = vec![
        (bursty_arrivals("mmpp", 80.0, 2_000.0).unwrap(), 25.0),
        (bursty_arrivals("flash", 60.0, 2_000.0).unwrap(), 50.0),
        (bursty_arrivals("diurnal", 40.0, 2_000.0).unwrap(), 75.0),
    ];
    let lazy: Vec<Request> = MergedStream::new(&specs, 2_000.0, 9).collect();
    assert!(lazy.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    assert_eq!(lazy, merged_stream(&specs, 2_000.0, 9), "eager adapter diverged from lazy merge");
}

#[test]
fn boundary_streams_behave() {
    // Zero-rate and empty-trace processes are silent, not wedged.
    assert!(collect(&Arrivals::Poisson { rate: 0.0 }, 1_000.0, 1).is_empty());
    assert!(collect(&Arrivals::trace(vec![]), 1_000.0, 1).is_empty());

    // A 1/s zero-jitter uniform stream lands exactly one request, at
    // exactly t = 1000 ms, inside a 1500 ms horizon...
    let one = Arrivals::Uniform { rate: 1.0, jitter: 0.0 };
    let a = collect(&one, 1_500.0, 1);
    assert_eq!(a.len(), 1);
    assert_eq!(a[0].arrival, 1_000_000);
    assert_eq!(a[0].deadline, 1_100_000);
    // ...and a horizon-exact arrival is EXCLUDED: the horizon is
    // half-open, `[0, horizon)`.
    assert!(collect(&one, 1_000.0, 1).is_empty(), "t = horizon must be excluded");

    // An empty merge (and one whose every source is silent) is a
    // well-behaved exhausted stream from the first peek.
    for specs in [vec![], vec![(Arrivals::Poisson { rate: 0.0 }, 10.0); 3]] {
        let mut s = MergedStream::new(&specs, 1_000.0, 1);
        assert_eq!(s.peek_time(), None);
        assert_eq!(s.buffered(), 0);
        assert!(s.next_request().is_none());
    }
    // Single-request merge: peeks agree, then drain to None.
    let mut s = MergedStream::new(&[(one.clone(), 100.0)], 1_500.0, 1);
    assert_eq!(s.peek_time(), Some(1_000_000));
    assert_eq!(s.peek_model(0), Some(1_000_000));
    let r = s.next_request().unwrap();
    assert_eq!((r.id, r.model, r.arrival), (0, 0, 1_000_000));
    assert!(s.peek_time().is_none() && s.peek_model(0).is_none());
}
