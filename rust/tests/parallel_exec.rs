//! Determinism contract of the cluster execution core
//! (`cluster::exec`): a fixed (placement, routing, seed, stream) tuple
//! must produce a byte-identical `ClusterReport` JSON for any thread
//! count, on all three cluster drivers — static placement, adaptive
//! control plane, and lifecycle memory manager. Plus the compile-time
//! `Send` assertions that keep every `Policy` implementation eligible
//! for the worker pool.

use dstack::cluster::{
    fig12_workload, place, run_placement_with, GpuSched, Parallelism, PlacementPolicy,
    RoutingPolicy,
};
use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive_with, AdaptiveCfg};
use dstack::lifecycle::{longtail_gpus, longtail_workload, serve_longtail_with, LifecycleCfg};
use dstack::profile::{T4, V100};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Render the canonical scenarios' reports under `threads`.
fn report_strings(threads: usize) -> [String; 4] {
    let t = Parallelism::Threads(threads);

    // Static: the Fig. 12 mix knee-packed onto a heterogeneous cluster,
    // JSQ-routed (backlog probes at every barrier).
    let (profiles, rates, reqs) = fig12_workload(1_500.0, 77);
    let gpus = [V100.clone(), T4.clone(), T4.clone()];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::FirstFitDecreasing);
    let stat = run_placement_with(
        &profiles,
        &gpus,
        &pl,
        &reqs,
        1_500.0,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        7,
        "det",
        t,
    )
    .to_json()
    .to_string_pretty();

    // Static, wide: 6 GPUs clears the core's fan-out threshold, so the
    // worker pool actually runs (the 2-3 GPU scenarios above take the
    // serial bypass) — this row is what makes the property non-vacuous.
    let gpus6 = vec![T4.clone(); 6];
    let pl6 = place(&profiles, &rates, &gpus6, PlacementPolicy::LoadBalance);
    let wide = run_placement_with(
        &profiles,
        &gpus6,
        &pl6,
        &reqs,
        1_500.0,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        7,
        "det6",
        t,
    )
    .to_json()
    .to_string_pretty();

    // Adaptive: the canonical drifting workload long enough to cross
    // the midpoint swap, so control ticks, replans and replica surgery
    // all land inside the horizon.
    let (profiles, initial, _peak, reqs) = drift_workload(3_000.0, 11);
    let cfg = AdaptiveCfg { interval_ms: 250.0, cooldown_ticks: 1, ..Default::default() };
    let adap = run_adaptive_with(
        &profiles,
        &initial,
        &drift_gpus(),
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        &reqs,
        3_000.0,
        11,
        t,
    )
    .to_json()
    .to_string_pretty();

    // Lifecycle: a memory-pressured long-tail fleet, so cold starts,
    // evictions, parking and scale-to-zero all fire.
    let (profiles, rates, reqs) = longtail_workload(10, 1.1, 350.0, 1_500.0, 13);
    let lcfg = LifecycleCfg {
        mem_budget_mib: 2_048,
        idle_timeout_ms: 400.0,
        ..Default::default()
    };
    let lc = serve_longtail_with(
        &profiles,
        &rates,
        &longtail_gpus(),
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &lcfg,
        &reqs,
        1_500.0,
        13,
        t,
    )
    .to_json()
    .to_string_pretty();

    [stat, wide, adap, lc]
}

#[test]
fn reports_are_byte_identical_across_thread_counts() {
    let baseline = report_strings(THREAD_COUNTS[0]);
    // The scenarios must actually exercise their machinery, or the
    // property would vacuously pass on an idle cluster.
    assert!(baseline[2].contains("\"adaptive\""), "no adaptive stats attached");
    assert!(baseline[3].contains("\"lifecycle\""), "no lifecycle stats attached");
    for &threads in &THREAD_COUNTS[1..] {
        let got = report_strings(threads);
        for (i, name) in ["static", "static-wide", "adaptive", "lifecycle"].iter().enumerate() {
            assert_eq!(
                baseline[i], got[i],
                "{name} report diverged between threads=1 and threads={threads}"
            );
        }
    }
}

#[test]
fn auto_parallelism_matches_serial() {
    // Whatever `auto` resolves to on this host, results are the serial
    // results — the property that makes Auto a safe default everywhere.
    let (profiles, rates, reqs) = fig12_workload(1_000.0, 21);
    let gpus = [T4.clone(), T4.clone(), T4.clone(), T4.clone()];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::LoadBalance);
    let run = |t: Parallelism| {
        run_placement_with(
            &profiles,
            &gpus,
            &pl,
            &reqs,
            1_000.0,
            RoutingPolicy::PowerOfTwoChoices,
            GpuSched::Dstack,
            3,
            "auto",
            t,
        )
        .to_json()
        .to_string_compact()
    };
    assert_eq!(run(Parallelism::Threads(1)), run(Parallelism::Auto));
}

/// `Policy: Send` is what lets the execution core ship engines to its
/// worker pool. Pin the bound per implementation so a future field
/// (an `Rc`, a raw pointer) fails here with a readable error instead of
/// deep inside the pool's generics.
#[test]
fn every_policy_impl_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<dstack::sched::dstack::Dstack>();
    assert_send::<dstack::sched::temporal::Temporal>();
    assert_send::<dstack::sched::triton::Triton>();
    assert_send::<dstack::sched::gslice::Gslice>();
    assert_send::<dstack::sched::fixed_batch::FixedBatch>();
    assert_send::<dstack::sched::max_throughput::MaxThroughput>();
    assert_send::<dstack::sched::max_min::MaxMin>();
    assert_send::<Box<dyn dstack::sim::Policy>>();
}
