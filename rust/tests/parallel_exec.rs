//! Determinism contract of the cluster execution core
//! (`cluster::exec`): a fixed (placement, routing, seed, stream) tuple
//! must produce a byte-identical `ClusterReport` JSON for any thread
//! count AND either barrier discipline (`exec_mode` epoch | sparse), on
//! all three cluster drivers — static placement, adaptive control
//! plane, and lifecycle memory manager. The scenario matrix includes a
//! round-robin row (exercising sparse mode's barrier-elision path), a
//! rejected-model row (zero-replica candidate sets), and the drifting
//! workload (mid-stream tombstone surgery + pending activations). Plus
//! the compile-time `Send` assertions that keep every `Policy`
//! implementation eligible for the worker pool.

use dstack::cluster::{
    fig12_specs, fig12_workload, place, run_placement_stream, run_placement_with,
    serve_cluster_stream_overload, ExecMode, ExecOpts, GpuSched, Parallelism, PlacementPolicy,
    RoutingPolicy,
};
use dstack::controlplane::{
    drift_gpus, drift_specs, drift_workload, run_adaptive_stream, run_adaptive_with, AdaptiveCfg,
};
use dstack::faults::{FaultEvent, FaultKind, ResilienceCfg};
use dstack::gpu::ms_to_us;
use dstack::lifecycle::{
    longtail_gpus, longtail_specs, longtail_workload, serve_longtail_stream,
    serve_longtail_stream_faults, serve_longtail_with, LifecycleCfg,
};
use dstack::profile::{T4, V100};
use dstack::unified::{
    drifting_longtail_specs, drifting_longtail_workload, run_unified_stream, run_unified_with,
    unified_gpus, UnifiedCfg,
};
use dstack::overload::{expand_profiles, OverloadCfg, OverloadSpec, VariantSpec};
use dstack::workload::{MaterializedStream, MergedStream};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];
const MODES: [ExecMode; 2] = [ExecMode::Epoch, ExecMode::Sparse];

const SCENARIOS: [&str; 10] = [
    "static-jsq",
    "static-wide-jsq",
    "static-wide-rr",
    "static-rejected",
    "adaptive-jsq",
    "adaptive-rr",
    "lifecycle",
    "unified",
    "lifecycle-faults",
    "static-overload",
];

/// Render the canonical scenarios' reports under `opts`. `streamed`
/// selects the ingestion path: `false` materializes each workload into
/// a `Vec<Request>` first (the classic entry points), `true` feeds the
/// drivers the lazy [`MergedStream`] directly (the `_stream` entry
/// points) — the contract under test is that the choice is invisible
/// in the report bytes.
fn report_strings(opts: ExecOpts, streamed: bool) -> Vec<String> {
    let mut out = Vec::with_capacity(SCENARIOS.len());

    // Static: the Fig. 12 mix knee-packed onto a heterogeneous cluster,
    // JSQ-routed (backlog probes at every barrier).
    let (profiles, rates, specs) = fig12_specs();
    let (_, _, reqs) = fig12_workload(1_500.0, 77);
    let gpus = [V100.clone(), T4.clone(), T4.clone()];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::FirstFitDecreasing);
    // One closure per driver keeps the two ingestion paths visibly
    // identical in everything but the stream argument.
    let run_static = |gpus: &[dstack::profile::GpuSpec],
                      pl: &dstack::cluster::Placement,
                      routing: RoutingPolicy,
                      label: &str| {
        let rep = if streamed {
            run_placement_stream(
                &profiles,
                gpus,
                pl,
                MergedStream::new(&specs, 1_500.0, 77),
                1_500.0,
                routing,
                GpuSched::Dstack,
                7,
                label,
                opts,
            )
        } else {
            run_placement_with(
                &profiles,
                gpus,
                pl,
                reqs.clone(),
                1_500.0,
                routing,
                GpuSched::Dstack,
                7,
                label,
                opts,
            )
        };
        rep.to_json().to_string_pretty()
    };
    out.push(run_static(&gpus, &pl, RoutingPolicy::JoinShortestQueue, "det"));

    // Static, wide: 6 GPUs clears the core's fan-out threshold, so the
    // worker pool actually runs (the 2-3 GPU scenarios above take the
    // serial bypass) — this row is what makes the property non-vacuous.
    // Once JSQ (per-arrival candidate sync + backlog probes)...
    let gpus6 = vec![T4.clone(); 6];
    let pl6 = place(&profiles, &rates, &gpus6, PlacementPolicy::LoadBalance);
    out.push(run_static(&gpus6, &pl6, RoutingPolicy::JoinShortestQueue, "det6"));
    // ...and once round-robin: backlog-free routing, so sparse mode
    // elides every stepping barrier and batches the whole un-quantized
    // stream into timestamped injection rounds.
    out.push(run_static(&gpus6, &pl6, RoutingPolicy::RoundRobin, "det6rr"));

    // Static, overloaded: a single T4 cannot admit the whole mix, so
    // some models run with *zero replicas* — empty candidate sets whose
    // arrivals must reject without synchronizing (or touching) anyone.
    let gpus1 = [T4.clone()];
    let pl1 = place(&profiles, &rates, &gpus1, PlacementPolicy::FirstFitDecreasing);
    out.push(run_static(&gpus1, &pl1, RoutingPolicy::JoinShortestQueue, "det1"));

    // Adaptive: the canonical drifting workload long enough to cross
    // the midpoint swap, so control ticks, replans and replica surgery
    // all land inside the horizon — JSQ and (elidable) RR variants.
    let (profiles, initial, _peak, specs) = drift_specs(3_000.0);
    let (_, _, _, reqs) = drift_workload(3_000.0, 11);
    let cfg = AdaptiveCfg { interval_ms: 250.0, cooldown_ticks: 1, ..Default::default() };
    for routing in [RoutingPolicy::JoinShortestQueue, RoutingPolicy::RoundRobin] {
        out.push(
            if streamed {
                run_adaptive_stream(
                    &profiles,
                    &initial,
                    &drift_gpus(),
                    PlacementPolicy::FirstFitDecreasing,
                    routing,
                    GpuSched::Dstack,
                    &cfg,
                    MergedStream::new(&specs, 3_000.0, 11),
                    3_000.0,
                    11,
                    opts,
                )
            } else {
                run_adaptive_with(
                    &profiles,
                    &initial,
                    &drift_gpus(),
                    PlacementPolicy::FirstFitDecreasing,
                    routing,
                    GpuSched::Dstack,
                    &cfg,
                    reqs.clone(),
                    3_000.0,
                    11,
                    opts,
                )
            }
            .to_json()
            .to_string_pretty(),
        );
    }

    // Lifecycle: a memory-pressured long-tail fleet, so cold starts,
    // evictions, parking and scale-to-zero all fire (conservative
    // all-engines candidate sets in sparse mode).
    let (profiles, rates, specs) = longtail_specs(10, 1.1, 350.0);
    let (_, _, reqs) = longtail_workload(10, 1.1, 350.0, 1_500.0, 13);
    let lcfg = LifecycleCfg {
        mem_budget_mib: 2_048,
        idle_timeout_ms: 400.0,
        ..Default::default()
    };
    out.push(
        if streamed {
            serve_longtail_stream(
                &profiles,
                &rates,
                &longtail_gpus(),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &lcfg,
                MergedStream::new(&specs, 1_500.0, 13),
                1_500.0,
                13,
                opts,
            )
        } else {
            serve_longtail_with(
                &profiles,
                &rates,
                &longtail_gpus(),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &lcfg,
                reqs,
                1_500.0,
                13,
                opts,
            )
        }
        .to_json()
        .to_string_pretty(),
    );

    // Unified: the drift + memory-pressure stress scenario — replan
    // surgery (tombstone adds, warm releases, drained re-dispatch) on
    // top of cold starts, evictions and component-bounded candidate
    // sets, all mid-flight. The hardest determinism row in the matrix.
    let (profiles, rates, specs) = drifting_longtail_specs(12, 1.1, 450.0, 2_000.0);
    let (_, _, reqs) = drifting_longtail_workload(12, 1.1, 450.0, 2_000.0, 17);
    let ucfg = UnifiedCfg {
        lifecycle: LifecycleCfg { mem_budget_mib: 3_072, min_replicas: 1, ..Default::default() },
        ..Default::default()
    };
    out.push(
        if streamed {
            run_unified_stream(
                &profiles,
                &rates,
                &unified_gpus(4),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &ucfg,
                MergedStream::new(&specs, 2_000.0, 17),
                2_000.0,
                17,
                opts,
            )
        } else {
            run_unified_with(
                &profiles,
                &rates,
                &unified_gpus(4),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &ucfg,
                reqs,
                2_000.0,
                17,
                opts,
            )
        }
        .to_json()
        .to_string_pretty(),
    );

    // Faults: the memory-pressured long-tail fleet again, now through a
    // scripted degrade→down→up cycle with the full front door armed
    // (deadline admission + hedged re-dispatch + SLO classes). Store
    // crashes, cascade re-routes of the drained queue, hedge sweeps and
    // cold on-demand recovery must all land on driver-event barriers —
    // this row is what pins the tentpole claim that fault scenarios stay
    // byte-identical across exec modes, thread counts and ingestion.
    let (fprofiles, frates, fspecs) = longtail_specs(10, 1.1, 350.0);
    let (_, _, freqs) = longtail_workload(10, 1.1, 350.0, 1_500.0, 13);
    let flcfg = LifecycleCfg {
        mem_budget_mib: 2_048,
        idle_timeout_ms: 400.0,
        ..Default::default()
    };
    let fcfg = ResilienceCfg {
        events: vec![
            FaultEvent { t: ms_to_us(350.0), gpu: 0, kind: FaultKind::Degraded },
            FaultEvent { t: ms_to_us(600.0), gpu: 1, kind: FaultKind::Down },
            FaultEvent { t: ms_to_us(1_000.0), gpu: 1, kind: FaultKind::Up },
        ],
        bulk_models: vec!["vgg19".into(), "bert".into()],
        admission: true,
        ..Default::default()
    };
    out.push(
        if streamed {
            serve_longtail_stream_faults(
                &fprofiles,
                &frates,
                &longtail_gpus(),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &flcfg,
                MergedStream::new(&fspecs, 1_500.0, 13),
                1_500.0,
                13,
                opts,
                Some(&fcfg),
            )
        } else {
            serve_longtail_stream_faults(
                &fprofiles,
                &frates,
                &longtail_gpus(),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &flcfg,
                MaterializedStream::new(freqs, fprofiles.len()),
                1_500.0,
                13,
                opts,
                Some(&fcfg),
            )
        }
        .to_json()
        .to_string_pretty(),
    );

    // Overload: the Fig. 12 mix squeezed onto two T4s with the full
    // overload layer armed — a declared brownout variant, retry
    // backoff and circuit breakers. Retry releases merge into the
    // driver's event stream and breaker/brownout decisions resolve at
    // arrival barriers, so this row pins the PR's determinism claim the
    // same way the faults row pins PR 9's.
    let (oprofiles_base, orates_base, ospecs) = fig12_specs();
    let (_, _, oreqs) = fig12_workload(1_500.0, 77);
    let odecl = VariantSpec {
        name: "fig12_int8".into(),
        knee_pct: 15,
        latency_scale: 0.5,
        mem_mib: 300,
    };
    let (oprofiles, omap) = expand_profiles(&oprofiles_base, &[(0, odecl)]).unwrap();
    let ospec = OverloadSpec {
        cfg: OverloadCfg { max_retries: 2, breaker_k: 6, ..Default::default() },
        map: omap,
    };
    let mut orates = orates_base.clone();
    orates.resize(oprofiles.len(), 0.0);
    let ogpus = [T4.clone(), T4.clone()];
    out.push(
        if streamed {
            serve_cluster_stream_overload(
                &oprofiles,
                &orates,
                &ogpus,
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                MergedStream::new(&ospecs, 1_500.0, 77),
                1_500.0,
                7,
                opts,
                None,
                Some(&ospec),
            )
        } else {
            serve_cluster_stream_overload(
                &oprofiles,
                &orates,
                &ogpus,
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                MaterializedStream::new(oreqs, oprofiles.len()),
                1_500.0,
                7,
                opts,
                None,
                Some(&ospec),
            )
        }
        .to_json()
        .to_string_pretty(),
    );

    out
}

#[test]
fn reports_are_byte_identical_across_threads_and_modes() {
    let base_opts =
        ExecOpts { threads: Parallelism::Threads(1), mode: ExecMode::Epoch, ..Default::default() };
    let baseline = report_strings(base_opts, false);
    // The scenarios must actually exercise their machinery, or the
    // property would vacuously pass on an idle cluster.
    assert!(baseline[4].contains("\"adaptive\""), "no adaptive stats attached");
    assert!(baseline[6].contains("\"lifecycle\""), "no lifecycle stats attached");
    assert!(baseline[3].contains("false"), "single-T4 scenario rejected no model");
    // The unified row must carry BOTH control planes and actually pay
    // footprint-priced migrations, or its identity check is vacuous.
    assert!(
        baseline[7].contains("\"adaptive\"") && baseline[7].contains("\"lifecycle\""),
        "unified scenario lost a control plane"
    );
    assert!(
        baseline[7].contains("\"cold_migration_ms\""),
        "unified scenario did not price migrations"
    );
    // The fault row must actually attach front-door telemetry, or its
    // identity check degenerates into the plain lifecycle row.
    assert!(
        baseline[8].contains("\"resilience\""),
        "fault scenario attached no resilience stats"
    );
    // The overload row must attach overload telemetry and actually
    // schedule retries, or its identity check degenerates into the
    // plain static row.
    assert!(
        baseline[9].contains("\"overload\"") && baseline[9].contains("\"retries_scheduled\""),
        "overload scenario attached no overload stats"
    );
    for streamed in [false, true] {
        for mode in MODES {
            for &threads in &THREAD_COUNTS {
                if !streamed && mode == ExecMode::Epoch && threads == THREAD_COUNTS[0] {
                    continue; // the baseline itself
                }
                let got = report_strings(
                    ExecOpts { threads: Parallelism::Threads(threads), mode, ..Default::default() },
                    streamed,
                );
                for (i, name) in SCENARIOS.iter().enumerate() {
                    assert_eq!(
                        baseline[i],
                        got[i],
                        "{name} report diverged from (materialized, epoch, threads=1) at \
                         (streamed={streamed}, {mode:?}, threads={threads})"
                    );
                }
            }
        }
    }
}

#[test]
fn streamed_ingestion_is_actually_lazy() {
    // The identity matrix above would pass even if the `_stream` entry
    // points secretly collected the stream into a `Vec`. The execution
    // core's own accounting rules that out: on a round-robin stream in
    // sparse mode the peak number of requests buffered anywhere between
    // generator and engines must stay far below the workload size (at
    // most one elision chunk plus the per-model merge heads).
    let (profiles, rates, specs) = fig12_specs();
    let gpus = vec![T4.clone(); 6];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::LoadBalance);
    let rep = run_placement_stream(
        &profiles,
        &gpus,
        &pl,
        MergedStream::new(&specs, 1_500.0, 77),
        1_500.0,
        RoutingPolicy::RoundRobin,
        GpuSched::Dstack,
        7,
        "lazy",
        ExecOpts { threads: Parallelism::Threads(1), mode: ExecMode::Sparse, ..Default::default() },
    );
    let x = rep.exec.expect("exec stats attached");
    assert!(x.requests_streamed > 2_000, "workload too small to be probative: {x:?}");
    assert!(x.peak_in_flight > 0, "no in-flight accounting: {x:?}");
    // Bound: one elision chunk (1024), plus the merge heads, plus the
    // slack a same-instant group may add when it straddles the cap.
    assert!(
        x.peak_in_flight <= 1_024 + 64,
        "streamed path buffered {} of {} requests — stream was materialized somewhere",
        x.peak_in_flight,
        x.requests_streamed
    );
    // JSQ drains every arrival at its own barrier: the in-flight peak
    // collapses to roughly the merge heads plus one same-instant group.
    let rep = run_placement_stream(
        &profiles,
        &gpus,
        &pl,
        MergedStream::new(&specs, 1_500.0, 77),
        1_500.0,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        7,
        "lazy",
        ExecOpts { threads: Parallelism::Threads(1), mode: ExecMode::Sparse, ..Default::default() },
    );
    let x = rep.exec.expect("exec stats attached");
    assert!(
        x.peak_in_flight <= 64,
        "JSQ streamed peak {} should be O(merge heads)",
        x.peak_in_flight
    );
}

#[test]
fn sparse_mode_actually_elides_rr_barriers() {
    // The elision path must really engage on round-robin streams (the
    // identity test above would pass even if sparse silently fell back
    // to per-arrival barriers).
    let (profiles, rates, reqs) = fig12_workload(1_000.0, 21);
    let gpus = vec![T4.clone(); 4];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::LoadBalance);
    let run = |routing| {
        run_placement_with(
            &profiles,
            &gpus,
            &pl,
            reqs.clone(),
            1_000.0,
            routing,
            GpuSched::Dstack,
            3,
            "elide",
            ExecOpts {
                threads: Parallelism::Threads(1),
                mode: ExecMode::Sparse,
                ..Default::default()
            },
        )
        .exec
        .expect("exec stats attached")
    };
    let rr = run(RoutingPolicy::RoundRobin);
    assert!(rr.barriers_elided > 0, "RR stream elided no barriers: {rr:?}");
    assert!(rr.arrivals_batched > 0);
    assert!(rr.elision_ratio() > 0.5, "elision ratio {:.2}", rr.elision_ratio());
    // JSQ reads backlogs at every arrival: nothing may be elided.
    let jsq = run(RoutingPolicy::JoinShortestQueue);
    assert_eq!(jsq.barriers_elided, 0, "JSQ must not elide barriers: {jsq:?}");
    assert_eq!(jsq.arrivals_batched, 0);
}

#[test]
fn auto_parallelism_matches_serial() {
    // Whatever `auto` resolves to on this host, results are the serial
    // results — the property that makes Auto a safe default everywhere.
    let (profiles, rates, reqs) = fig12_workload(1_000.0, 21);
    let gpus = [T4.clone(), T4.clone(), T4.clone(), T4.clone()];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::LoadBalance);
    let run = |t: Parallelism| {
        run_placement_with(
            &profiles,
            &gpus,
            &pl,
            reqs.clone(),
            1_000.0,
            RoutingPolicy::PowerOfTwoChoices,
            GpuSched::Dstack,
            3,
            "auto",
            ExecOpts::with_threads(t),
        )
        .to_json()
        .to_string_compact()
    };
    assert_eq!(run(Parallelism::Threads(1)), run(Parallelism::Auto));
    // And the streamed path under Auto agrees too.
    let (sprofiles, srates, specs) = fig12_specs();
    let spl = place(&sprofiles, &srates, &gpus, PlacementPolicy::LoadBalance);
    let run_s = |t: Parallelism| {
        run_placement_stream(
            &sprofiles,
            &gpus,
            &spl,
            MergedStream::new(&specs, 1_000.0, 21),
            1_000.0,
            RoutingPolicy::PowerOfTwoChoices,
            GpuSched::Dstack,
            3,
            "auto",
            ExecOpts::with_threads(t),
        )
        .to_json()
        .to_string_compact()
    };
    assert_eq!(run_s(Parallelism::Threads(1)), run_s(Parallelism::Auto));
}

/// `Policy: Send` is what lets the execution core ship engines to its
/// worker pool. Pin the bound per implementation so a future field
/// (an `Rc`, a raw pointer) fails here with a readable error instead of
/// deep inside the pool's generics.
#[test]
fn every_policy_impl_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<dstack::sched::dstack::Dstack>();
    assert_send::<dstack::sched::temporal::Temporal>();
    assert_send::<dstack::sched::triton::Triton>();
    assert_send::<dstack::sched::gslice::Gslice>();
    assert_send::<dstack::sched::fixed_batch::FixedBatch>();
    assert_send::<dstack::sched::max_throughput::MaxThroughput>();
    assert_send::<dstack::sched::max_min::MaxMin>();
    assert_send::<Box<dyn dstack::sim::Policy>>();
}
