//! Lifecycle subsystem integration + property tests: the long-tail
//! acceptance scenario (shipped config), request conservation under
//! cold starts/evictions/scale-to-zero, memory-accounting conservation
//! of the [`ModelStore`], and router/tombstone safety (JSQ/P2C/RR never
//! dispatch to a deactivated replica).

use dstack::cluster::{GpuSched, PlacementPolicy, Replica, Router, RoutingPolicy};
use dstack::lifecycle::{
    longtail_gpus, longtail_workload, serve_longtail, EvictionPolicy, LifecycleCfg, ModelStore,
};
use dstack::prop_assert;
use dstack::util::prop::Cases;
use std::path::PathBuf;

fn config_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/cluster_longtail_zipf.json")
}

#[test]
fn shipped_longtail_scenario_meets_acceptance() {
    // The `dstack lifecycle --config rust/configs/cluster_longtail_zipf.json`
    // acceptance run, at a test-friendly horizon: a 24-model Zipf(1.1)
    // fleet on 2 GPUs whose combined resident budget holds fewer than
    // half the fleet's weights.
    let mut sc = dstack::config::Scenario::from_file(&config_path()).expect("shipped config");
    sc.horizon_ms = 4_000.0;
    let lc = sc.lifecycle.clone().expect("lifecycle block");
    assert_eq!(lc.n_models, 24);
    let rep = dstack::config::run_lifecycle_scenario(&sc);
    let stats = rep.lifecycle.as_ref().expect("lifecycle stats attached");

    // The working set really oversubscribes the budget by > 2x.
    let budgets: u64 = 2 * lc.cfg.mem_budget_mib;
    let total_mem = 26_700; // 24 cycled zoo models (see profile::zoo)
    assert!(total_mem > 2 * budgets, "scenario no longer memory-oversubscribed");

    // Eviction and cold-start machinery actually engaged.
    assert!(stats.cold_starts > 0, "no cold starts");
    assert!(stats.evictions > 0, "no evictions");
    assert!(stats.mib_loaded > 0);
    assert!(stats.warm_hits > 0, "the head should stay warm");

    // Resident memory never exceeded the budget on either GPU, and at
    // the horizon fewer than half the fleet is resident anywhere.
    for (g, &peak) in stats.peak_resident_mib.iter().enumerate() {
        assert!(peak <= lc.cfg.mem_budget_mib, "gpu {g}: peak {peak} MiB over budget");
    }
    let resident_total: u64 = stats.resident_final.iter().sum();
    assert!(resident_total <= 12, "more than half the fleet resident: {resident_total}");

    // Zero admission of requests to never-resident models: a model
    // without replicas serves nothing and counts every request as
    // rejected; everything else was admitted deliberately.
    for m in 0..24 {
        if !rep.admitted[m] {
            assert_eq!(rep.served[m], 0, "never-resident model {m} served traffic");
            assert!(rep.replica_map[m].is_empty());
        }
    }
    assert!(rep.total_throughput() > 0.0);
    assert!(stats.goodput_rps > 0.0);
}

#[test]
fn warmness_aware_routing_beats_oblivious_jsq() {
    // The bench_lifecycle acceptance pinned as a test: warmness-aware
    // routing must reach warm-oblivious JSQ's goodput at no worse an
    // SLO miss rate on the long-tail fleet.
    let horizon_ms = 3_000.0;
    let seed = 77;
    let (profiles, rates, reqs) = longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = longtail_gpus();
    let run = |warm: bool| {
        serve_longtail(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &LifecycleCfg { warm_routing: warm, mem_budget_mib: 4_096, ..Default::default() },
            reqs.clone(),
            horizon_ms,
            seed,
        )
    };
    let oblivious = run(false);
    let warm = run(true);
    let g = |r: &dstack::cluster::ClusterReport| r.lifecycle.as_ref().unwrap().goodput_rps;
    let v = |r: &dstack::cluster::ClusterReport| r.violations_per_sec.iter().sum::<f64>();
    assert!(
        g(&warm) >= g(&oblivious),
        "warmness-aware goodput {} < oblivious {}",
        g(&warm),
        g(&oblivious)
    );
    assert!(
        v(&warm) <= v(&oblivious) + 1e-9,
        "warmness-aware viol/s {} > oblivious {}",
        v(&warm),
        v(&oblivious)
    );
}

#[test]
fn lifecycle_conserves_requests_on_random_fleets() {
    Cases::new(6).run(|g| {
        let n = g.usize_in(4, 10);
        let total = g.f64_in(100.0, 400.0);
        let seed = g.u64();
        let horizon_ms = 800.0;
        let (profiles, rates, reqs) = longtail_workload(n, 1.1, total, horizon_ms, seed);
        let cfg = LifecycleCfg {
            eviction: *g.pick(EvictionPolicy::all()),
            mem_budget_mib: [2_048, 3_072, 0][g.usize_in(0, 2)],
            idle_timeout_ms: [0.0, 400.0][g.usize_in(0, 1)],
            warm_routing: g.bool(),
            ..Default::default()
        };
        let routing = *g.pick(RoutingPolicy::all());
        let rep = serve_longtail(
            &profiles,
            &rates,
            &longtail_gpus(),
            PlacementPolicy::LoadBalance,
            routing,
            GpuSched::Dstack,
            &cfg,
            reqs.clone(),
            horizon_ms,
            seed,
        );
        // 1. Conservation: every offered request is served, dropped or
        //    rejected — across cold starts, evictions and re-routes.
        let mut offered = vec![0u64; n];
        for r in &reqs {
            offered[r.model] += 1;
        }
        for m in 0..n {
            prop_assert!(
                rep.served[m] + rep.dropped[m] + rep.rejected[m] == offered[m],
                "model {m}: {} + {} + {} != {}",
                rep.served[m],
                rep.dropped[m],
                rep.rejected[m],
                offered[m]
            );
            prop_assert!(rep.admitted[m] || rep.served[m] == 0, "rejected model {m} served");
        }
        // 2. Resident memory never exceeded the per-GPU budget.
        let stats = rep.lifecycle.as_ref().expect("stats");
        for (gi, &peak) in stats.peak_resident_mib.iter().enumerate() {
            let budget = if cfg.mem_budget_mib == 0 { 16 * 1024 } else { cfg.mem_budget_mib };
            prop_assert!(peak <= budget, "gpu {gi}: peak {peak} > budget {budget}");
        }
        // 3. Served work only lands on assigned replicas.
        for (gi, gr) in rep.per_gpu.iter().enumerate() {
            for share in &gr.models {
                prop_assert!(
                    rep.replica_map[share.model].contains(&gi),
                    "gpu {gi} served model {} without hosting it",
                    share.model
                );
            }
        }
        // 4. Determinism.
        let again = serve_longtail(
            &profiles,
            &rates,
            &longtail_gpus(),
            PlacementPolicy::LoadBalance,
            routing,
            GpuSched::Dstack,
            &cfg,
            reqs.clone(),
            horizon_ms,
            seed,
        );
        prop_assert!(
            rep.to_json().to_string_compact() == again.to_json().to_string_compact(),
            "non-deterministic lifecycle report"
        );
        Ok(())
    });
}

#[test]
fn model_store_accounting_conserves_memory() {
    // Random load/evict/release/reload sequences: `used_mib` always
    // equals the sum of resident footprints, never exceeds capacity,
    // and every eviction frees exactly the victim's footprint.
    Cases::new(128).run(|g| {
        let capacity = 2_000 + g.usize_in(0, 4_000) as u64;
        let policy = *g.pick(EvictionPolicy::all());
        let mut store = ModelStore::new(capacity, policy);
        let n_models = g.usize_in(3, 12);
        let mems: Vec<u64> = (0..n_models).map(|_| 200 + g.usize_in(0, 1_500) as u64).collect();
        let mut now = 0u64;
        for _ in 0..64 {
            now += g.usize_in(1, 1_000) as u64;
            let m = g.usize_in(0, n_models - 1);
            match g.usize_in(0, 3) {
                0 => {
                    if !store.is_resident(m) {
                        if let Some(victims) = store.begin_load(now, m, mems[m], 300.0, false) {
                            for v in &victims {
                                prop_assert!(*v != m, "evicted the model being loaded");
                                prop_assert!(!store.is_resident(*v), "victim still resident");
                            }
                            store.complete_load(now, m);
                        } else {
                            prop_assert!(
                                mems[m] > capacity,
                                "load of {} MiB failed under capacity {capacity} with no pins",
                                mems[m]
                            );
                        }
                    }
                }
                1 => store.touch(now, m),
                2 => {
                    store.release(m);
                }
                _ => {
                    if store.is_warm(m) {
                        prop_assert!(store.release(m), "warm unpinned release refused");
                        prop_assert!(!store.is_resident(m));
                    }
                }
            }
            // Invariant: accounting conserves memory after every op.
            let sum: u64 = store.residents().iter().map(|r| r.mem_mib).sum();
            prop_assert!(store.used_mib() == sum, "used {} != sum {sum}", store.used_mib());
            prop_assert!(store.used_mib() <= capacity, "store over capacity");
            prop_assert!(store.peak_mib() <= capacity);
        }
        Ok(())
    });
}

#[test]
fn router_never_dispatches_to_tombstoned_replicas() {
    // The contract every cluster driver (controlplane, lifecycle)
    // relies on: the routable set passed to the router excludes
    // deactivated (tombstoned) replicas, and the router — under every
    // policy — only ever returns an index into that set. Random replica
    // sets with random tombstone patterns, all three policies.
    Cases::new(128).run(|g| {
        let n_total = g.usize_in(1, 6);
        let active: Vec<bool> = (0..n_total).map(|_| g.bool()).collect();
        let all: Vec<(Replica, bool)> = (0..n_total)
            .map(|i| {
                let rep = Replica {
                    gpu: i,
                    local: g.usize_in(0, 3),
                    pct: 20 + 10 * (i as u32 % 4),
                    batch: 16,
                    capacity_rps: 100.0 + i as f64,
                };
                (rep, active[i])
            })
            .collect();
        // The driver-side filter (controlplane::routable_of semantics).
        let routable: Vec<Replica> =
            all.iter().filter(|(_, a)| *a).map(|(r, _)| r.clone()).collect();
        if routable.is_empty() {
            // Drivers count these requests as rejected and never call
            // the router — nothing to check.
            return Ok(());
        }
        for policy in RoutingPolicy::all() {
            let mut router = Router::new(*policy, 1, g.u64());
            for _ in 0..16 {
                let backlogs: Vec<usize> =
                    (0..routable.len()).map(|_| g.usize_in(0, 20)).collect();
                let pick = router.route(0, &routable, |r| {
                    backlogs[routable.iter().position(|x| x.gpu == r.gpu).unwrap()]
                });
                prop_assert!(pick < routable.len(), "{policy:?} picked out of range");
                prop_assert!(
                    active[routable[pick].gpu],
                    "{policy:?} dispatched to a tombstoned replica"
                );
            }
        }
        Ok(())
    });
}
