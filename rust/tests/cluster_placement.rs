//! Property-based invariants of the cluster placement engine and the
//! load-aware router (mini harness, see `util::prop`): random model
//! mixes, rates, heterogeneous GPU sets, placement and routing policies
//! — the packing and routing invariants must hold on every case.

use dstack::cluster::{
    place, serve_cluster, GpuSched, PlacementPolicy, RoutingPolicy,
};
use dstack::profile::{by_name, GpuSpec, ModelProfile, T4, V100};
use dstack::prop_assert;
use dstack::util::prop::{Cases, Gen};
use dstack::workload::{merged_stream, Arrivals};

const ZOO: &[&str] =
    &["mobilenet", "alexnet", "bert", "resnet50", "vgg19", "resnet18", "inception", "resnext50"];

fn random_models(g: &mut Gen, max: usize) -> (Vec<ModelProfile>, Vec<f64>) {
    let names = g.subset(ZOO, 2);
    let n = names.len().min(max);
    let profiles: Vec<ModelProfile> =
        names[..n].iter().map(|m| by_name(m).unwrap()).collect();
    let rates: Vec<f64> = (0..n).map(|_| g.f64_in(50.0, 700.0)).collect();
    (profiles, rates)
}

fn random_gpus(g: &mut Gen, lo: usize, hi: usize) -> Vec<GpuSpec> {
    (0..g.usize_in(lo, hi))
        .map(|_| if g.bool() { V100.clone() } else { T4.clone() })
        .collect()
}

#[test]
fn placement_invariants_hold_on_random_clusters() {
    Cases::new(48).run(|g| {
        let (profiles, rates) = random_models(g, 6);
        let gpus = random_gpus(g, 1, 5);
        let policy = *g.pick(PlacementPolicy::all());
        let p = place(&profiles, &rates, &gpus, policy);

        // 1. No GPU is packed beyond 100% knee budget.
        for (gi, load) in p.knee_load.iter().enumerate() {
            prop_assert!(*load <= 100, "{policy:?}: gpu {gi} at {load}% knee load");
        }
        // 2. Admitted ⇔ at least one replica; rejected ⇔ none.
        for m in 0..profiles.len() {
            prop_assert!(
                p.admitted[m] == !p.replicas[m].is_empty(),
                "model {m}: admitted={} but {} replicas",
                p.admitted[m],
                p.replicas[m].len()
            );
        }
        // 3. hosted/replica cross-references agree; ≤ 1 replica per GPU.
        for (m, reps) in p.replicas.iter().enumerate() {
            let mut seen_gpus = Vec::new();
            for r in reps {
                prop_assert!(r.gpu < gpus.len(), "replica on gpu {} of {}", r.gpu, gpus.len());
                prop_assert!(
                    p.hosted[r.gpu].get(r.local) == Some(&m),
                    "hosted[{}][{}] != model {m}",
                    r.gpu,
                    r.local
                );
                prop_assert!(!seen_gpus.contains(&r.gpu), "model {m} twice on gpu {}", r.gpu);
                seen_gpus.push(r.gpu);
                prop_assert!(r.capacity_rps > 0.0, "replica with zero capacity");
            }
        }
        // 4. Fully covered models really have the capacity; shed is the
        //    exact uncovered remainder (with headroom).
        for m in 0..profiles.len() {
            prop_assert!(p.shed_rps[m] >= 0.0);
            if p.admitted[m] && p.shed_rps[m] == 0.0 {
                prop_assert!(
                    p.capacity_rps(m) + 1e-9 >= rates[m],
                    "model {m}: capacity {} < offered {}",
                    p.capacity_rps(m),
                    rates[m]
                );
            }
        }
        // 5. Determinism: the same inputs repack identically.
        let q = place(&profiles, &rates, &gpus, policy);
        prop_assert!(p.knee_load == q.knee_load && p.hosted == q.hosted);
        Ok(())
    });
}

#[test]
fn routed_cluster_invariants_hold_end_to_end() {
    Cases::new(6).run(|g| {
        let (profiles, rates) = random_models(g, 3);
        let gpus = random_gpus(g, 2, 3);
        let placement = *g.pick(PlacementPolicy::all());
        let routing = *g.pick(RoutingPolicy::all());
        let seed = g.u64();
        let horizon_ms = 400.0;
        let specs: Vec<_> = profiles
            .iter()
            .zip(&rates)
            .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, horizon_ms, seed);

        let run = || {
            serve_cluster(
                &profiles,
                &rates,
                &gpus,
                placement,
                routing,
                GpuSched::Dstack,
                reqs.clone(),
                horizon_ms,
                seed,
            )
        };
        let rep = run();

        // 1. Identical seeds ⇒ identical ClusterReport (bitwise, via the
        //    deterministic JSON form).
        let again = run();
        prop_assert!(
            rep.to_json().to_string_compact() == again.to_json().to_string_compact(),
            "{placement:?}+{routing:?}: non-deterministic report"
        );
        // 2. Request conservation: served + dropped + rejected = offered.
        let mut offered = vec![0u64; profiles.len()];
        for r in &reqs {
            offered[r.model] += 1;
        }
        for m in 0..profiles.len() {
            prop_assert!(
                rep.served[m] + rep.dropped[m] + rep.rejected[m] == offered[m],
                "model {m}: {} + {} + {} != {}",
                rep.served[m],
                rep.dropped[m],
                rep.rejected[m],
                offered[m]
            );
            prop_assert!(
                rep.admitted[m] || rep.served[m] == 0,
                "rejected model {m} served requests"
            );
        }
        // 3. The router never lands work on a GPU that hosts no replica
        //    of the model: every served share sits inside the replica
        //    map (JSQ/P2C sample backlogs only across true replicas).
        for (gi, gr) in rep.per_gpu.iter().enumerate() {
            for share in &gr.models {
                prop_assert!(
                    rep.replica_map[share.model].contains(&gi),
                    "gpu {gi} served model {} without hosting it",
                    share.model
                );
            }
        }
        // 4. Utilization is a valid fraction on every GPU.
        for (gi, u) in rep.gpu_utilization.iter().enumerate() {
            prop_assert!((0.0..=1.0).contains(u), "gpu {gi} utilization {u}");
        }
        Ok(())
    });
}

#[test]
fn heterogeneous_jsq_cluster_beats_legacy_round_robin_split() {
    // The bench_cluster acceptance scenario, pinned as a test: on the
    // same seeded Fig. 12 workload, a heterogeneous 2×V100 + 2×T4
    // cluster with knee-packed placement and JSQ routing must reach at
    // least the legacy all-on-every-T4 round-robin D-STACK throughput.
    use dstack::cluster::{fig12_workload, run_cluster, ClusterPolicy};
    let horizon_ms = 2_000.0;
    let (profiles, rates, reqs) = fig12_workload(horizon_ms, 77);

    let legacy =
        run_cluster(&profiles, &T4, 4, reqs.clone(), horizon_ms, ClusterPolicy::DstackAll);
    let hetero_gpus = [V100.clone(), V100.clone(), T4.clone(), T4.clone()];
    let placed = serve_cluster(
        &profiles,
        &rates,
        &hetero_gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        reqs,
        horizon_ms,
        7,
    );
    assert!(
        placed.total_throughput() >= legacy.total_throughput(),
        "hetero JSQ {} < legacy RR {}",
        placed.total_throughput(),
        legacy.total_throughput()
    );
}
