//! Fault injection and the resilient front door (`faults`,
//! DESIGN.md §4.12), driver-level: timeline validation at the config
//! boundary, drain conservation through engine-down/up cycles (no
//! request lost or double-served — served + dropped + rejected always
//! equals the offered stream per model), the zero-routable-replica
//! guard, deadline admission by SLO class, and hedge determinism (two
//! identical runs are byte-identical, and so are epoch vs sparse at any
//! thread count). Complements the unit tests in `faults::tests` (health
//! machine, MTBF generation, tie-breaks) and the full mode × thread ×
//! ingestion identity matrix in `tests/parallel_exec.rs`.

use dstack::cluster::{
    serve_cluster_stream_faults, ClusterReport, ExecMode, ExecOpts, GpuSched, Parallelism,
    PlacementPolicy, RoutingPolicy,
};
use dstack::config::Scenario;
use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive_stream_faults, AdaptiveCfg};
use dstack::faults::{FaultEvent, FaultKind, ResilienceCfg};
use dstack::gpu::ms_to_us;
use dstack::lifecycle::{
    longtail_gpus, longtail_workload, serve_longtail_stream_faults, LifecycleCfg,
};
use dstack::profile::{by_name, ModelProfile, T4, V100};
use dstack::workload::{merged_stream, Arrivals, MaterializedStream, Request};
use std::path::PathBuf;

fn offered_counts(reqs: &[Request], n_models: usize) -> Vec<u64> {
    let mut off = vec![0u64; n_models];
    for r in reqs {
        off[r.model] += 1;
    }
    off
}

/// The drain-conservation invariant: whatever faults, re-routes, hedges
/// and rejects happened, every offered request is accounted exactly
/// once per model.
fn assert_conserved(rep: &ClusterReport, offered: &[u64], label: &str) {
    for m in 0..offered.len() {
        assert_eq!(
            rep.served[m] + rep.dropped[m] + rep.rejected[m],
            offered[m],
            "{label}: model {m} lost or double-served requests \
             (served {} + dropped {} + rejected {} != offered {})",
            rep.served[m],
            rep.dropped[m],
            rep.rejected[m],
            offered[m]
        );
    }
}

fn c4() -> (Vec<ModelProfile>, Vec<f64>) {
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let rates = vec![700.0, 700.0, 320.0, 160.0];
    (profiles, rates)
}

fn c4_requests(rates: &[f64], profiles: &[ModelProfile], horizon_ms: f64, seed: u64) -> Vec<Request> {
    let specs: Vec<_> = profiles
        .iter()
        .zip(rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    merged_stream(&specs, horizon_ms, seed)
}

fn ev(t_ms: f64, gpu: usize, kind: FaultKind) -> FaultEvent {
    FaultEvent { t: ms_to_us(t_ms), gpu, kind }
}

// ---------------------------------------------------------------------------
// Timeline parsing/validation at the config boundary.
// ---------------------------------------------------------------------------

#[test]
fn config_rejects_invalid_fault_timelines() {
    let base = |faults: &str| {
        format!(
            r#"{{"name": "t", "horizon_ms": 1000,
                 "cluster": {{"gpus": ["T4", "T4"], "placement": "lb", "routing": "jsq"}},
                 "models": [{{"name": "alexnet", "rate": 100}}],
                 "faults": {faults}}}"#
        )
    };
    // GPU index out of range.
    assert!(Scenario::from_json(&base(
        r#"{"events": [{"t_ms": 100, "gpu": 7, "kind": "engine_down"}]}"#
    ))
    .is_err());
    // Illegal transition: up without a preceding down/degraded.
    assert!(Scenario::from_json(&base(
        r#"{"events": [{"t_ms": 100, "gpu": 0, "kind": "engine_up"}]}"#
    ))
    .is_err());
    // Double down on the same engine.
    assert!(Scenario::from_json(&base(
        r#"{"events": [{"t_ms": 100, "gpu": 0, "kind": "down"},
                        {"t_ms": 200, "gpu": 0, "kind": "down"}]}"#
    ))
    .is_err());
    // Unknown kind and non-positive time.
    assert!(Scenario::from_json(&base(
        r#"{"events": [{"t_ms": 100, "gpu": 0, "kind": "explode"}]}"#
    ))
    .is_err());
    assert!(Scenario::from_json(&base(
        r#"{"events": [{"t_ms": 0, "gpu": 0, "kind": "down"}]}"#
    ))
    .is_err());
    // A legal cycle parses, and short kind aliases work.
    let sc = Scenario::from_json(&base(
        r#"{"events": [{"t_ms": 100, "gpu": 0, "kind": "degraded"},
                        {"t_ms": 200, "gpu": 0, "kind": "down"},
                        {"t_ms": 400, "gpu": 0, "kind": "up"}],
             "bulk_models": ["alexnet"], "admission": true}"#,
    ))
    .expect("legal timeline must parse");
    let f = sc.faults.as_ref().expect("faults block attached");
    assert_eq!(f.events.len(), 3);
    assert!(f.admission);
}

// ---------------------------------------------------------------------------
// Drain conservation through down/up cycles, all recovery models.
// ---------------------------------------------------------------------------

#[test]
fn lifecycle_cycle_conserves_and_reroutes() {
    // ModelStore driver: the downed engine's store crashes and recovery
    // is on demand (weights fault back in per arrival). The drained
    // queue cascades through the re-route path and lands somewhere —
    // nothing may be lost across the cycle.
    let (profiles, rates, reqs) = longtail_workload(16, 1.1, 500.0, 3_000.0, 7);
    let offered = offered_counts(&reqs, profiles.len());
    let lcfg = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };
    let fcfg = ResilienceCfg {
        events: vec![ev(1_200.0, 1, FaultKind::Down), ev(2_000.0, 1, FaultKind::Up)],
        ..Default::default()
    };
    let rep = serve_longtail_stream_faults(
        &profiles,
        &rates,
        &longtail_gpus(),
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &lcfg,
        MaterializedStream::new(reqs, profiles.len()),
        3_000.0,
        7,
        ExecOpts::default(),
        Some(&fcfg),
    );
    assert_conserved(&rep, &offered, "lifecycle cycle");
    let res = rep.resilience.expect("fault run must attach resilience stats");
    assert_eq!(res.fault_events, 2);
    assert_eq!(res.engine_downs, 1);
    assert!(
        res.rerouted_on_failure > 0,
        "a 500 req/s memory-pressured fleet must have had a queue to drain"
    );
    assert!(
        res.availability_pct > 0.0 && res.availability_pct < 100.0,
        "one engine down for >=800 ms of a 2x3000 ms span: got {}",
        res.availability_pct
    );
}

#[test]
fn naive_front_door_rejects_the_drained_queue() {
    // reroute = false is the naive baseline: the drained queue is
    // rejected instead of cascaded. Conservation must still hold, and
    // the reroute counter must stay at zero.
    let (profiles, rates, reqs) = longtail_workload(16, 1.1, 500.0, 3_000.0, 7);
    let offered = offered_counts(&reqs, profiles.len());
    let lcfg = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };
    let fcfg = ResilienceCfg {
        events: vec![ev(1_200.0, 1, FaultKind::Down), ev(2_000.0, 1, FaultKind::Up)],
        reroute: false,
        hedge: false,
        ..Default::default()
    };
    let rep = serve_longtail_stream_faults(
        &profiles,
        &rates,
        &longtail_gpus(),
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &lcfg,
        MaterializedStream::new(reqs, profiles.len()),
        3_000.0,
        7,
        ExecOpts::default(),
        Some(&fcfg),
    );
    assert_conserved(&rep, &offered, "naive cycle");
    let res = rep.resilience.expect("resilience stats");
    assert_eq!(res.rerouted_on_failure, 0, "naive mode must not re-route");
    assert_eq!(res.hedges_fired, 0, "naive mode must not hedge");
    assert!(
        rep.rejected.iter().sum::<u64>() > 0,
        "the drained queue must surface as typed rejects"
    );
}

#[test]
fn static_cycle_conserves_and_recovers_cold() {
    // Static driver: eager restore — the engine re-activates after a
    // cold re-load of everything it hosts, and the report still
    // balances.
    let (profiles, rates) = c4();
    let reqs = c4_requests(&rates, &profiles, 2_000.0, 5);
    let offered = offered_counts(&reqs, profiles.len());
    let gpus = [V100.clone(), T4.clone(), T4.clone()];
    let fcfg = ResilienceCfg {
        events: vec![ev(600.0, 1, FaultKind::Down), ev(1_200.0, 1, FaultKind::Up)],
        ..Default::default()
    };
    let rep = serve_cluster_stream_faults(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        MaterializedStream::new(reqs, profiles.len()),
        2_000.0,
        5,
        ExecOpts::default(),
        Some(&fcfg),
    );
    assert_conserved(&rep, &offered, "static cycle");
    let res = rep.resilience.expect("resilience stats");
    assert_eq!(res.engine_downs, 1);
    assert!(res.availability_pct > 0.0 && res.availability_pct < 100.0);
}

#[test]
fn adaptive_cycle_conserves_with_eager_restore() {
    // Adaptive driver: the cycle overlaps control ticks and a drift
    // replan; the estimator and the fault layer must not double-count.
    let (profiles, initial, _peak, reqs) = drift_workload(2_000.0, 11);
    let offered = offered_counts(&reqs, profiles.len());
    let cfg = AdaptiveCfg { interval_ms: 250.0, cooldown_ticks: 1, ..Default::default() };
    let fcfg = ResilienceCfg {
        events: vec![ev(700.0, 0, FaultKind::Down), ev(1_400.0, 0, FaultKind::Up)],
        ..Default::default()
    };
    let rep = run_adaptive_stream_faults(
        &profiles,
        &initial,
        &drift_gpus(),
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        MaterializedStream::new(reqs, profiles.len()),
        2_000.0,
        11,
        ExecOpts::default(),
        Some(&fcfg),
    );
    assert_conserved(&rep, &offered, "adaptive cycle");
    let res = rep.resilience.expect("resilience stats");
    assert_eq!(res.engine_downs, 1);
    assert!(rep.adaptive.is_some(), "fault wiring must not drop the adaptive stats");
}

// ---------------------------------------------------------------------------
// The zero-routable-replica guard.
// ---------------------------------------------------------------------------

#[test]
fn zero_routable_window_rejects_typed() {
    // Both engines down, never up: every arrival in the outage window
    // must route to the typed unroutable reject — counted, stamped,
    // conserved — instead of silently holding until the horizon drop.
    let (profiles, rates) = c4();
    let reqs = c4_requests(&rates, &profiles, 1_500.0, 3);
    let offered = offered_counts(&reqs, profiles.len());
    let gpus = [T4.clone(), T4.clone()];
    let fcfg = ResilienceCfg {
        events: vec![ev(500.0, 0, FaultKind::Down), ev(500.0, 1, FaultKind::Down)],
        ..Default::default()
    };
    let rep = serve_cluster_stream_faults(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        MaterializedStream::new(reqs, profiles.len()),
        1_500.0,
        3,
        ExecOpts::default(),
        Some(&fcfg),
    );
    assert_conserved(&rep, &offered, "total outage");
    let res = rep.resilience.expect("resilience stats");
    assert!(
        res.unroutable_rejects > 0,
        "arrivals during a total outage must become typed unroutable rejects"
    );
    // Two engines down from 500 ms to the 1500 ms horizon = 2/3 uptime.
    assert!(
        (res.availability_pct - 100.0 * (1.0 - 1_000.0 / 3_000.0)).abs() < 1e-6,
        "availability integral is off: {}",
        res.availability_pct
    );
}

// ---------------------------------------------------------------------------
// Deadline admission by SLO class.
// ---------------------------------------------------------------------------

#[test]
fn deadline_admission_rejects_by_class() {
    // Losing one of two engines mid-run piles the survivor's queue far
    // past any deadline budget: with admission armed, arrivals whose
    // best-case estimate cannot make their deadline are rejected at the
    // front door, tallied per SLO class.
    let (profiles, rates) = c4();
    let reqs = c4_requests(&rates, &profiles, 2_000.0, 9);
    let offered = offered_counts(&reqs, profiles.len());
    let gpus = [T4.clone(), T4.clone()];
    let fcfg = ResilienceCfg {
        events: vec![ev(800.0, 1, FaultKind::Down)],
        bulk_models: vec!["vgg19".into()],
        admission: true,
        ..Default::default()
    };
    let rep = serve_cluster_stream_faults(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        MaterializedStream::new(reqs, profiles.len()),
        2_000.0,
        9,
        ExecOpts::default(),
        Some(&fcfg),
    );
    assert_conserved(&rep, &offered, "admission");
    let res = rep.resilience.expect("resilience stats");
    assert!(
        res.deadline_rejects_critical + res.deadline_rejects_bulk > 0,
        "an overloaded survivor must trip deadline admission"
    );
}

// ---------------------------------------------------------------------------
// Hedge determinism.
// ---------------------------------------------------------------------------

#[test]
fn hedge_sweep_fires_and_is_deterministic() {
    // A permanently degraded engine with tight hedge thresholds: the
    // sweep must actually fire, every won hedge must also be a fired
    // hedge, and the whole run — analytic first-completion-wins, ties
    // broken by engine index — must reproduce byte-for-byte, in both
    // exec modes and at any thread count.
    let (profiles, rates, reqs) = longtail_workload(24, 1.1, 600.0, 3_000.0, 42);
    let offered = offered_counts(&reqs, profiles.len());
    let lcfg = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };
    let fcfg = ResilienceCfg {
        events: vec![ev(1_000.0, 1, FaultKind::Degraded)],
        hedge_check_ms: 20.0,
        hedge_critical_ms: 5.0,
        hedge_bulk_ms: 50.0,
        ..Default::default()
    };
    let run = |opts: ExecOpts| {
        serve_longtail_stream_faults(
            &profiles,
            &rates,
            &longtail_gpus(),
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &lcfg,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            3_000.0,
            42,
            opts,
            Some(&fcfg),
        )
    };
    let serial = ExecOpts {
        threads: Parallelism::Threads(1),
        mode: ExecMode::Epoch,
        ..Default::default()
    };
    let a = run(serial);
    assert_conserved(&a, &offered, "hedged run");
    let res = a.resilience.as_ref().expect("resilience stats");
    assert!(
        res.hedges_fired > 0,
        "a 2 s degraded window at a 20 ms cadence must find stuck requests"
    );
    assert!(res.hedges_won <= res.hedges_fired, "won hedges are a subset of fired hedges");
    let a_json = a.to_json().to_string_pretty();
    // Same inputs, same bytes — twice serially, then sparse + threaded.
    assert_eq!(a_json, run(serial).to_json().to_string_pretty(), "repeat run diverged");
    let sparse = ExecOpts {
        threads: Parallelism::Threads(2),
        mode: ExecMode::Sparse,
        ..Default::default()
    };
    assert_eq!(
        a_json,
        run(sparse).to_json().to_string_pretty(),
        "hedged run diverged across exec mode x threads"
    );
}

// ---------------------------------------------------------------------------
// The shipped scenario file.
// ---------------------------------------------------------------------------

#[test]
fn shipped_engine_failure_scenario_runs() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/cluster_engine_failure.json");
    let sc = Scenario::from_file(&path).expect("shipped config must load");
    let f = sc.faults.as_ref().expect("config must carry a faults block");
    assert!(f.admission, "the shipped scenario arms deadline admission");
    assert!(!f.bulk_models.is_empty(), "the shipped scenario declares SLO classes");
    let rep = dstack::config::run_cluster_scenario(&sc);
    let res = rep.resilience.expect("fault run must attach resilience stats");
    assert!(res.engine_downs >= 1, "the shipped timeline takes an engine down");
    assert!(res.fault_events >= 3);
    assert!(res.availability_pct < 100.0);
}
