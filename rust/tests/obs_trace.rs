//! Determinism contract of the observability layer (`dstack::obs`,
//! DESIGN.md §4.11): with recording enabled, the exported Perfetto
//! trace and time-series JSON must be **byte-identical** across
//! `exec_mode` (epoch | sparse) × thread count — the same contract the
//! report bytes already obey (`tests/parallel_exec.rs`) — and enabling
//! recording must not move a single byte of the `ClusterReport` JSON
//! itself. Sampling must be a pure function of the seed (same seed ⇒
//! same kept set, in any mode), and the windowed series must cover the
//! horizon exactly and conserve completion counts against the report.

use dstack::cluster::{
    ClusterReport, ExecMode, ExecOpts, GpuSched, Parallelism, PlacementPolicy, RoutingPolicy,
};
use dstack::lifecycle::{longtail_gpus, longtail_workload, serve_longtail_with, LifecycleCfg};
use dstack::obs::ObsCfg;
use dstack::unified::{drifting_longtail_workload, run_unified_with, unified_gpus, UnifiedCfg};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];
const MODES: [ExecMode; 2] = [ExecMode::Epoch, ExecMode::Sparse];

fn opts(mode: ExecMode, threads: usize, obs: ObsCfg) -> ExecOpts {
    ExecOpts { threads: Parallelism::Threads(threads), mode, obs }
}

/// The hardest trace scenario: the unified driver's drift + memory
/// pressure stress (replan surgery, cold starts, evictions, held
/// requests) — every event kind the control lane can emit.
fn run_unified(o: ExecOpts) -> ClusterReport {
    let (profiles, rates, reqs) = drifting_longtail_workload(12, 1.1, 450.0, 2_000.0, 17);
    let cfg = UnifiedCfg {
        lifecycle: LifecycleCfg { mem_budget_mib: 3_072, min_replicas: 1, ..Default::default() },
        ..Default::default()
    };
    run_unified_with(
        &profiles,
        &rates,
        &unified_gpus(4),
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        2_000.0,
        17,
        o,
    )
}

/// The lifecycle driver's long-tail scenario — the other control-lane
/// implementation (scale-to-zero, parking) gets its own identity row.
fn run_lifecycle(o: ExecOpts) -> ClusterReport {
    let (profiles, rates, reqs) = longtail_workload(10, 1.1, 350.0, 1_500.0, 13);
    let cfg = LifecycleCfg { mem_budget_mib: 2_048, idle_timeout_ms: 400.0, ..Default::default() };
    serve_longtail_with(
        &profiles,
        &rates,
        &longtail_gpus(),
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        1_500.0,
        13,
        o,
    )
}

fn obs_all() -> ObsCfg {
    ObsCfg { trace: true, timeseries: true, ..Default::default() }
}

/// (trace bytes, timeseries bytes) for a finished run.
fn artifacts(rep: &ClusterReport) -> (String, String) {
    let obs = rep.obs.as_ref().expect("recording was enabled");
    (obs.to_perfetto(), obs.timeseries_json().to_string_pretty())
}

#[test]
fn traces_are_byte_identical_across_modes_and_threads() {
    let baseline = run_unified(opts(ExecMode::Epoch, 1, obs_all()));
    let (trace0, series0) = artifacts(&baseline);
    // Non-vacuity: the scenario must actually exercise the full event
    // vocabulary, or identity would hold trivially on an empty trace.
    for kind in ["arrive", "enqueue", "batch", "complete", "replan", "cold_load"] {
        assert!(trace0.contains(&format!("\"name\":\"{kind}\"")), "no {kind} events in trace");
    }
    let obs = baseline.obs.as_ref().unwrap();
    assert!(obs.events_recorded() > 1_000, "trace too small to be probative");
    assert_eq!(obs.sampled_out(), 0, "default config must keep every event");
    for mode in MODES {
        for &threads in &THREAD_COUNTS {
            if mode == ExecMode::Epoch && threads == 1 {
                continue; // the baseline itself
            }
            let rep = run_unified(opts(mode, threads, obs_all()));
            let (trace, series) = artifacts(&rep);
            assert_eq!(trace0, trace, "unified trace diverged at ({mode:?}, threads={threads})");
            assert_eq!(
                series0, series,
                "unified timeseries diverged at ({mode:?}, threads={threads})"
            );
        }
    }
    // And the lifecycle driver's control lane (scale-to-zero, parking).
    let lbase = run_lifecycle(opts(ExecMode::Epoch, 1, obs_all()));
    let (ltrace0, lseries0) = artifacts(&lbase);
    for kind in ["scale_to_zero", "cold_load"] {
        assert!(ltrace0.contains(&format!("\"name\":\"{kind}\"")), "no {kind} events in trace");
    }
    for mode in MODES {
        for &threads in &THREAD_COUNTS {
            let rep = run_lifecycle(opts(mode, threads, obs_all()));
            let (trace, series) = artifacts(&rep);
            assert_eq!(ltrace0, trace, "lifecycle trace diverged at ({mode:?}, threads={threads})");
            assert_eq!(
                lseries0, series,
                "lifecycle timeseries diverged at ({mode:?}, threads={threads})"
            );
        }
    }
}

#[test]
fn enabling_observability_does_not_move_report_bytes() {
    let off = run_unified(opts(ExecMode::Sparse, 2, ObsCfg::default()));
    assert!(off.obs.is_none(), "recording off must attach no payload");
    let on = run_unified(opts(ExecMode::Sparse, 2, obs_all()));
    assert!(on.obs.is_some(), "recording on must attach the payload");
    assert_eq!(
        off.to_json().to_string_pretty(),
        on.to_json().to_string_pretty(),
        "enabling tracing/timeseries changed the report JSON"
    );
}

#[test]
fn sampling_is_deterministic_and_mode_invariant() {
    let sampled = ObsCfg {
        trace: true,
        sample_request: 8,
        sample_gpu: 4,
        sample_control: 2,
        sampling_seed: 7,
        ..Default::default()
    };
    let base = run_unified(opts(ExecMode::Epoch, 1, sampled));
    let trace0 = base.obs.as_ref().unwrap().to_perfetto();
    // Same seed, different exec mode and thread count: the kept set is
    // a pure function of (seed, kind, per-kind sequence), so the trace
    // bytes cannot move.
    let again = run_unified(opts(ExecMode::Sparse, 8, sampled));
    assert_eq!(trace0, again.obs.as_ref().unwrap().to_perfetto());
    // The thinning is real: candidate counts match the unsampled run,
    // kept events are strictly fewer.
    let full = run_unified(opts(ExecMode::Epoch, 1, ObsCfg { trace: true, ..Default::default() }));
    let (fo, so) = (full.obs.as_ref().unwrap(), base.obs.as_ref().unwrap());
    assert_eq!(fo.candidates(), so.candidates(), "sampling must not change what is witnessed");
    assert!(so.events_recorded() < fo.events_recorded(), "sampling kept everything");
    assert_eq!(so.events_recorded() + so.sampled_out(), so.candidates());
    // A different seed keeps a different set.
    let other = run_unified(opts(ExecMode::Epoch, 1, ObsCfg { sampling_seed: 8, ..sampled }));
    assert_ne!(trace0, other.obs.as_ref().unwrap().to_perfetto());
}

#[test]
fn windows_cover_horizon_and_conserve_completions() {
    // 100 ms windows over a 2 000 ms horizon: exactly 20 buckets.
    let cfg = ObsCfg { timeseries: true, window_us: 100_000, ..Default::default() };
    let rep = run_unified(opts(ExecMode::Epoch, 1, cfg));
    let obs = rep.obs.as_ref().unwrap();
    assert_eq!(obs.n_windows(), 20, "windows must tile the horizon exactly");
    for lane in &obs.lanes {
        assert_eq!(lane.windows.len(), 20, "every lane pads to the full horizon");
    }
    // Completion conservation: windowed served counts sum to the
    // report's own served totals (horizon-exact completions clamp into
    // the last window rather than falling off the series).
    let windowed: u64 =
        obs.lanes.iter().flat_map(|l| l.windows.iter()).map(|w| w.served).sum();
    let reported: u64 = rep.served.iter().sum();
    assert_eq!(windowed, reported, "windowed served diverged from report served");
    // The series is non-trivial: traffic lands in many distinct
    // windows, and the drift scenario leaves some windows busier than
    // others (a flat series would make fig17 meaningless).
    let series = obs.timeseries_json();
    let rows = series.get("windows").unwrap().as_arr().unwrap().len();
    assert_eq!(rows, 20);
    assert_eq!(series.get("n_windows").unwrap().as_u64(), Some(20));
    let active = (0..20)
        .filter(|&i| obs.lanes.iter().any(|l| l.windows[i].served > 0))
        .count();
    assert!(active >= 10, "served traffic concentrated in only {active}/20 windows");
}

#[test]
fn histogram_quantiles_track_exact_quantiles() {
    // `exact_latencies: false` swaps the per-model p99 source from the
    // exact latency vectors to the log-bucketed histogram. The
    // histogram's ~1% relative-error guarantee must hold end-to-end on
    // a real run for every model that served traffic.
    let exact = run_unified(opts(ExecMode::Epoch, 1, ObsCfg::default()));
    let hist = run_unified(opts(
        ExecMode::Epoch,
        1,
        ObsCfg { exact_latencies: false, ..Default::default() },
    ));
    assert_eq!(exact.p99_ms.len(), hist.p99_ms.len());
    // Gate the relative-error check on sample count: below ~50 samples
    // the exact path's rank interpolation and the histogram's
    // ceil-rank pick can legitimately straddle an order-statistic gap.
    let mut checked = 0;
    for (m, (&e, &h)) in exact.p99_ms.iter().zip(&hist.p99_ms).enumerate() {
        if exact.served[m] == 0 {
            assert_eq!(h, e, "unserved model {m} must report identical (empty) p99");
            continue;
        }
        if exact.served[m] < 50 {
            continue;
        }
        checked += 1;
        let rel = (h - e).abs() / e.max(1e-9);
        assert!(rel < 0.05, "model {m} p99 drifted {rel:.4} (exact {e:.3} ms, hist {h:.3} ms)");
    }
    assert!(checked >= 3, "only {checked} models served ≥ 50 requests — scenario too small");
    // Everything else in the report is counter-driven and must not
    // move when the exact vectors are dropped.
    assert_eq!(exact.served, hist.served);
    assert_eq!(exact.dropped, hist.dropped);
    assert_eq!(exact.rejected, hist.rejected);
}
