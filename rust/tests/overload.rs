//! Driver-level contract of the overload-control layer (`overload`,
//! DESIGN.md §4.13): retry-with-backoff resolves at driver-event
//! barriers (byte-identical reports across exec mode × threads ×
//! ingestion), circuit breakers trip and recover inside real runs,
//! brownout serves declared variants with per-class degraded-goodput
//! accounting, the typed-reject taxonomy stays conservation-exact, and
//! the config boundary gates the `"overload"` block and `variants`
//! declarations. Complements the state-machine unit tests in
//! `overload::tests` and the full identity matrix row in
//! `tests/parallel_exec.rs`.

use dstack::cluster::{
    serve_cluster_stream_faults, serve_cluster_stream_overload, ClusterReport, ExecMode, ExecOpts,
    GpuSched, Parallelism, PlacementPolicy, RoutingPolicy,
};
use dstack::config::Scenario;
use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive_stream_overload, AdaptiveCfg};
use dstack::lifecycle::{longtail_gpus, longtail_workload, serve_longtail_stream_overload, LifecycleCfg};
use dstack::overload::{expand_profiles, OverloadCfg, OverloadSpec, VariantMap, VariantSpec};
use dstack::profile::{by_name, ModelProfile, T4, V100};
use dstack::unified::{drifting_longtail_workload, run_unified_stream_overload, unified_gpus, UnifiedCfg};
use dstack::workload::{merged_stream, Arrivals, MaterializedStream, Request};
use std::path::PathBuf;

fn offered_counts(reqs: &[Request], n_models: usize) -> Vec<u64> {
    let mut off = vec![0u64; n_models];
    for r in reqs {
        off[r.model] += 1;
    }
    off
}

/// Per-model conservation (exact when no brownout re-targeting happened).
fn assert_conserved(rep: &ClusterReport, offered: &[u64], label: &str) {
    for m in 0..offered.len() {
        assert_eq!(
            rep.served[m] + rep.dropped[m] + rep.rejected[m],
            offered[m],
            "{label}: model {m} lost or double-served requests"
        );
    }
}

/// Total conservation across the whole (possibly variant-expanded)
/// model space: brownout moves a request to a sibling index, never out
/// of the books.
fn assert_conserved_total(rep: &ClusterReport, offered: &[u64], label: &str) {
    let off: u64 = offered.iter().sum();
    let acc: u64 = (0..rep.served.len())
        .map(|m| rep.served[m] + rep.dropped[m] + rep.rejected[m])
        .sum();
    assert_eq!(acc, off, "{label}: expanded fleet lost or double-served requests");
}

fn c4() -> (Vec<ModelProfile>, Vec<f64>) {
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let rates = vec![700.0, 700.0, 320.0, 160.0];
    (profiles, rates)
}

fn c4_requests(rates: &[f64], profiles: &[ModelProfile], horizon_ms: f64, seed: u64) -> Vec<Request> {
    let specs: Vec<_> = profiles
        .iter()
        .zip(rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    merged_stream(&specs, horizon_ms, seed)
}

fn trivial_spec(cfg: OverloadCfg, n_models: usize) -> OverloadSpec {
    OverloadSpec { cfg, map: VariantMap::trivial(n_models) }
}

fn serial() -> ExecOpts {
    ExecOpts { threads: Parallelism::Threads(1), mode: ExecMode::Epoch, ..Default::default() }
}

// ---------------------------------------------------------------------------
// Retry-with-backoff: taxonomy exactness and cross-mode determinism.
// ---------------------------------------------------------------------------

#[test]
fn retry_backoff_conserves_types_and_reproduces() {
    // Two T4s cannot carry the c4 mix: deadline admission rejects pile
    // up and every one must flow through the retry queue. With retries
    // armed every *terminal* reject is typed retry_exhausted — the
    // rejected counters and the typed counters must balance exactly.
    let (profiles, rates) = c4();
    let reqs = c4_requests(&rates, &profiles, 2_000.0, 9);
    let offered = offered_counts(&reqs, profiles.len());
    let gpus = [T4.clone(), T4.clone()];
    let spec = trivial_spec(
        OverloadCfg { max_retries: 2, backoff_base_ms: 5.0, backoff_cap_ms: 40.0, ..Default::default() },
        profiles.len(),
    );
    let run = |opts: ExecOpts| {
        serve_cluster_stream_overload(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            2_000.0,
            9,
            opts,
            None,
            Some(&spec),
        )
    };
    let rep = run(serial());
    assert_conserved(&rep, &offered, "retry run");
    let o = rep.overload.as_ref().expect("overload run must attach overload stats");
    assert!(o.retries_scheduled > 0, "an overloaded front door must schedule retries");
    assert!(o.retries_succeeded <= o.retries_scheduled);
    let rejected_total: u64 = rep.rejected.iter().sum();
    assert_eq!(
        rejected_total,
        o.retry_exhausted_total(),
        "with retries armed every terminal reject must be typed retry_exhausted"
    );
    // Byte-identity: repeat, then sparse mode at higher thread counts.
    let a = rep.to_json().to_string_pretty();
    assert_eq!(a, run(serial()).to_json().to_string_pretty(), "repeat run diverged");
    for threads in [2usize, 8] {
        let opts = ExecOpts {
            threads: Parallelism::Threads(threads),
            mode: ExecMode::Sparse,
            ..Default::default()
        };
        assert_eq!(
            a,
            run(opts).to_json().to_string_pretty(),
            "retry run diverged at sparse/threads={threads}"
        );
    }
}

#[test]
fn retry_deadline_budget_exhaustion_is_typed() {
    // A backoff floor longer than any model's SLO window means no retry
    // can ever be scheduled (its release would land past the deadline):
    // the budget check must refuse them all and the terminal rejects
    // still carry the retry_exhausted type.
    let (profiles, rates) = c4();
    let reqs = c4_requests(&rates, &profiles, 1_500.0, 3);
    let offered = offered_counts(&reqs, profiles.len());
    let gpus = [T4.clone(), T4.clone()];
    let spec = trivial_spec(
        OverloadCfg {
            max_retries: 3,
            backoff_base_ms: 1_000.0,
            backoff_cap_ms: 1_000.0,
            ..Default::default()
        },
        profiles.len(),
    );
    let rep = serve_cluster_stream_overload(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        MaterializedStream::new(reqs, profiles.len()),
        1_500.0,
        3,
        serial(),
        None,
        Some(&spec),
    );
    assert_conserved(&rep, &offered, "deadline-budget run");
    let o = rep.overload.expect("overload stats");
    assert_eq!(o.retries_scheduled, 0, "a 1 s backoff can never meet a <1 s deadline");
    let rejected_total: u64 = rep.rejected.iter().sum();
    assert!(rejected_total > 0, "two T4s must reject part of the c4 mix");
    assert_eq!(rejected_total, o.retry_exhausted_total());
}

// ---------------------------------------------------------------------------
// Circuit breakers inside a real run.
// ---------------------------------------------------------------------------

#[test]
fn breakers_trip_during_flash_and_recover_after() {
    // A flash crowd on one model drives consecutive would-miss
    // estimates into the breakers; after the spike subsides the
    // half-open probe path must close them again (probes > 0). Retries
    // are off, so terminal causes keep their original types.
    let profiles = vec![by_name("resnet50").unwrap(), by_name("mobilenet").unwrap()];
    let rates = vec![250.0, 300.0];
    let specs = vec![
        (
            Arrivals::Flash { base: 250.0, mult: 6.0, spike_start_ms: 800.0, spike_ms: 1_200.0 },
            profiles[0].slo_ms,
        ),
        (Arrivals::Poisson { rate: 300.0 }, profiles[1].slo_ms),
    ];
    let reqs = merged_stream(&specs, 4_000.0, 21);
    let offered = offered_counts(&reqs, profiles.len());
    let gpus = [V100.clone(), T4.clone()];
    let spec = trivial_spec(
        OverloadCfg {
            max_retries: 0,
            breaker_k: 5,
            breaker_window_ms: 300.0,
            breaker_cooldown_ms: 100.0,
            ..Default::default()
        },
        profiles.len(),
    );
    let rep = serve_cluster_stream_overload(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        MaterializedStream::new(reqs, profiles.len()),
        4_000.0,
        21,
        serial(),
        None,
        Some(&spec),
    );
    assert_conserved(&rep, &offered, "breaker run");
    let o = rep.overload.expect("overload stats");
    assert!(o.breaker_trips > 0, "a 6x flash must trip a breaker");
    assert!(
        o.breaker_probes > 0,
        "post-spike traffic must half-open and close a breaker via a probe dispatch"
    );
    assert_eq!(o.retry_exhausted_total(), 0, "retries are off in this run");
}

// ---------------------------------------------------------------------------
// Brownout variant degradation (static driver).
// ---------------------------------------------------------------------------

#[test]
fn brownout_serves_variants_and_counts_goodput() {
    // resnet50 declares an int8 variant at half the runtime. During the
    // flash the primary's queue estimate blows its deadline and the
    // front door must fall back to the co-located variant — visible as
    // served requests on the variant index and per-class degraded
    // counters — while total conservation holds across the expanded
    // space. With brownout disabled the same workload serves no
    // variant at all.
    let base = vec![by_name("resnet50").unwrap(), by_name("mobilenet").unwrap()];
    let decl = VariantSpec {
        name: "resnet50_int8".into(),
        knee_pct: 20,
        latency_scale: 0.5,
        mem_mib: 400,
    };
    let (profiles, map) = expand_profiles(&base, &[(0, decl)]).unwrap();
    let v_idx = map.variants_of[0][0];
    let specs = vec![
        (
            Arrivals::Flash { base: 250.0, mult: 5.0, spike_start_ms: 700.0, spike_ms: 1_500.0 },
            base[0].slo_ms,
        ),
        (Arrivals::Poisson { rate: 350.0 }, base[1].slo_ms),
    ];
    let reqs = merged_stream(&specs, 3_500.0, 17);
    let offered = offered_counts(&reqs, profiles.len());
    let mut rates = vec![250.0, 350.0];
    rates.resize(profiles.len(), 0.0);
    let gpus = [V100.clone()];
    let run = |brownout: bool, opts: ExecOpts| {
        let spec = OverloadSpec {
            cfg: OverloadCfg { max_retries: 2, brownout, ..Default::default() },
            map: map.clone(),
        };
        serve_cluster_stream_overload(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            3_500.0,
            17,
            opts,
            None,
            Some(&spec),
        )
    };
    let rep = run(true, serial());
    assert_conserved_total(&rep, &offered, "brownout run");
    assert_eq!(offered[v_idx], 0, "variants must receive no direct arrivals");
    let o = rep.overload.as_ref().expect("overload stats");
    assert!(
        o.degraded_served_total() > 0,
        "the flash must push some requests onto the int8 variant"
    );
    assert_eq!(
        rep.served[v_idx], o.degraded_served_total(),
        "every variant-served request is exactly one degraded-served count"
    );
    // Brownout decisions happen at barriers too: full byte-identity.
    let a = rep.to_json().to_string_pretty();
    let sparse = ExecOpts {
        threads: Parallelism::Threads(4),
        mode: ExecMode::Sparse,
        ..Default::default()
    };
    assert_eq!(a, run(true, sparse).to_json().to_string_pretty(), "brownout run diverged");
    // Kill switch: same declarations, brownout off — no variant serving.
    let off = run(false, serial());
    assert_eq!(off.served[v_idx], 0);
    assert_eq!(off.overload.expect("stats").degraded_served_total(), 0);
}

// ---------------------------------------------------------------------------
// Lifecycle and unified drivers: residency-gated brownout, determinism.
// ---------------------------------------------------------------------------

#[test]
fn lifecycle_brownout_composes_with_residency() {
    // Memory-pressured long-tail fleet with variants declared for the
    // two head models. Variants are ordinary (zero-rate) residency
    // entries: brownout may only use them where the ModelStore already
    // has them warm — never a cold start. The observable contract here:
    // conservation over the expanded space, overload stats attached,
    // and byte-identity across exec modes.
    let (base, mut rates, reqs) = longtail_workload(10, 1.1, 500.0, 3_000.0, 7);
    let decls = vec![
        (
            0usize,
            VariantSpec { name: "lt0_int8".into(), knee_pct: 15, latency_scale: 0.5, mem_mib: 300 },
        ),
        (
            1usize,
            VariantSpec { name: "lt1_int8".into(), knee_pct: 15, latency_scale: 0.5, mem_mib: 300 },
        ),
    ];
    let (profiles, map) = expand_profiles(&base, &decls).unwrap();
    rates.resize(profiles.len(), 0.0);
    let offered = offered_counts(&reqs, profiles.len());
    let lcfg = LifecycleCfg { mem_budget_mib: 4_096, min_replicas: 1, ..Default::default() };
    let spec = OverloadSpec {
        cfg: OverloadCfg { max_retries: 2, breaker_k: 8, ..Default::default() },
        map,
    };
    let run = |opts: ExecOpts| {
        serve_longtail_stream_overload(
            &profiles,
            &rates,
            &longtail_gpus(),
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &lcfg,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            3_000.0,
            7,
            opts,
            None,
            Some(&spec),
        )
    };
    let rep = run(serial());
    assert_conserved_total(&rep, &offered, "lifecycle brownout");
    assert!(rep.overload.is_some(), "overload stats must attach");
    assert!(rep.lifecycle.is_some(), "overload wiring must not drop lifecycle stats");
    let a = rep.to_json().to_string_pretty();
    let sparse = ExecOpts {
        threads: Parallelism::Threads(2),
        mode: ExecMode::Sparse,
        ..Default::default()
    };
    assert_eq!(a, run(sparse).to_json().to_string_pretty(), "lifecycle brownout diverged");
}

#[test]
fn adaptive_and_unified_overload_reproduce() {
    // The remaining two drivers, retry + breaker armed (trivial variant
    // map — the scenario paths for these fleets do the same): per-model
    // conservation, stats attached alongside the drivers' own, and
    // byte-identity epoch vs sparse.
    let cfg = OverloadCfg { max_retries: 2, breaker_k: 6, ..Default::default() };
    let sparse = ExecOpts {
        threads: Parallelism::Threads(4),
        mode: ExecMode::Sparse,
        ..Default::default()
    };

    let (profiles, initial, _peak, reqs) = drift_workload(2_000.0, 11);
    let offered = offered_counts(&reqs, profiles.len());
    let acfg = AdaptiveCfg { interval_ms: 250.0, cooldown_ticks: 1, ..Default::default() };
    let spec = trivial_spec(cfg.clone(), profiles.len());
    let run_a = |opts: ExecOpts| {
        run_adaptive_stream_overload(
            &profiles,
            &initial,
            &drift_gpus(),
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &acfg,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            2_000.0,
            11,
            opts,
            None,
            Some(&spec),
        )
    };
    let rep = run_a(serial());
    assert_conserved(&rep, &offered, "adaptive overload");
    assert!(rep.overload.is_some() && rep.adaptive.is_some());
    assert_eq!(
        rep.to_json().to_string_pretty(),
        run_a(sparse).to_json().to_string_pretty(),
        "adaptive overload diverged"
    );

    let (uprofiles, urates, ureqs) = drifting_longtail_workload(12, 1.1, 450.0, 2_000.0, 17);
    let uoffered = offered_counts(&ureqs, uprofiles.len());
    let ucfg = UnifiedCfg {
        lifecycle: LifecycleCfg { mem_budget_mib: 3_072, min_replicas: 1, ..Default::default() },
        ..Default::default()
    };
    let uspec = trivial_spec(cfg, uprofiles.len());
    let run_u = |opts: ExecOpts| {
        run_unified_stream_overload(
            &uprofiles,
            &urates,
            &unified_gpus(4),
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &ucfg,
            MaterializedStream::new(ureqs.clone(), uprofiles.len()),
            2_000.0,
            17,
            opts,
            None,
            Some(&uspec),
        )
    };
    let urep = run_u(serial());
    assert_conserved(&urep, &uoffered, "unified overload");
    let o = urep.overload.as_ref().expect("overload stats");
    // Unified keeps an untyped reject path (replica sets crowded out
    // mid-reconfig), so typed rejects bound, not equal, the total.
    assert!(o.retry_exhausted_total() <= urep.rejected.iter().sum::<u64>());
    assert!(urep.adaptive.is_some() && urep.lifecycle.is_some());
    assert_eq!(
        urep.to_json().to_string_pretty(),
        run_u(sparse).to_json().to_string_pretty(),
        "unified overload diverged"
    );
}

// ---------------------------------------------------------------------------
// The Option<overload> seam: absent block, absent key, identical bytes.
// ---------------------------------------------------------------------------

#[test]
fn absent_overload_block_changes_nothing() {
    let (profiles, rates) = c4();
    let reqs = c4_requests(&rates, &profiles, 1_200.0, 5);
    let gpus = [V100.clone(), T4.clone()];
    let via_overload = serve_cluster_stream_overload(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        MaterializedStream::new(reqs.clone(), profiles.len()),
        1_200.0,
        5,
        serial(),
        None,
        None,
    )
    .to_json()
    .to_string_pretty();
    let via_faults = serve_cluster_stream_faults(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        MaterializedStream::new(reqs, profiles.len()),
        1_200.0,
        5,
        serial(),
        None,
    )
    .to_json()
    .to_string_pretty();
    assert_eq!(via_overload, via_faults, "a None overload layer must be invisible");
    assert!(
        !via_overload.contains("\"overload\""),
        "reports without an overload block must not grow an overload key"
    );
}

// ---------------------------------------------------------------------------
// Config boundary: the "overload" block and variants declarations.
// ---------------------------------------------------------------------------

#[test]
fn config_gates_overload_and_variants() {
    let base = |models: &str, extra: &str| {
        format!(
            r#"{{"name": "t", "horizon_ms": 1000,
                 "cluster": {{"gpus": ["V100"], "placement": "lb", "routing": "jsq"}},
                 "models": [{models}]{extra}}}"#
        )
    };
    let with_variant = r#"{"name": "resnet50", "rate": 100,
        "variants": [{"name": "resnet50_int8", "knee_pct": 20,
                      "latency_scale": 0.5, "mem_mib": 400}]}"#;
    // Variants without an overload block are rejected.
    assert!(Scenario::from_json(&base(with_variant, "")).is_err());
    // Variants with a lifecycle fleet are rejected.
    let lc = r#", "overload": {}, "lifecycle": {"n_models": 4, "alpha": 1.1,
                 "total_rps": 100, "mem_budget_mib": 2048}"#;
    assert!(Scenario::from_json(&base(with_variant, lc)).is_err());
    // Duplicate variant names are rejected at load, not at run.
    let dup = r#"{"name": "resnet50", "rate": 100,
        "variants": [{"name": "resnet50", "knee_pct": 20,
                      "latency_scale": 0.5, "mem_mib": 400}]}"#;
    assert!(Scenario::from_json(&base(dup, r#", "overload": {}"#)).is_err());
    // The legal form parses, expands, and round-trips.
    let sc = Scenario::from_json(&base(with_variant, r#", "overload": {"breaker_k": 4}"#))
        .expect("legal overload config must parse");
    let (profiles, spec) = sc
        .overload_expanded()
        .expect("expansion must succeed")
        .expect("overload block must expand");
    assert_eq!(profiles.len(), 2);
    assert_eq!(spec.map.n_primary, 1);
    assert_eq!(spec.cfg.breaker_k, 4);
    let back = Scenario::from_json(&sc.to_json().to_string_pretty())
        .expect("emitted overload config must re-parse");
    assert_eq!(back.models[0].variants.len(), 1);
    assert_eq!(back.overload.expect("overload survives round-trip").breaker_k, 4);
}

// ---------------------------------------------------------------------------
// The shipped scenario file.
// ---------------------------------------------------------------------------

#[test]
fn shipped_brownout_scenario_runs() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/cluster_brownout_flash.json");
    let sc = Scenario::from_file(&path).expect("shipped config must load");
    let ocfg = sc.overload.as_ref().expect("config must carry an overload block");
    assert!(ocfg.brownout && ocfg.max_retries > 0 && ocfg.breaker_k > 0);
    assert!(
        sc.models.iter().any(|m| !m.variants.is_empty()),
        "the shipped scenario declares brownout variants"
    );
    let rep = dstack::config::run_cluster_scenario(&sc);
    let o = rep.overload.expect("overload run must attach overload stats");
    assert!(
        o.retries_scheduled + o.degraded_served_total() + o.breaker_trips > 0,
        "the flash-crowd scenario must exercise the overload layer: {o:?}"
    );
    assert!(rep.served.iter().sum::<u64>() > 0);
}
