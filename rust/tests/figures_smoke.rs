//! Every table/figure generator runs and produces plausible data; the
//! headline paper *shapes* hold in the regenerated outputs.

use dstack::figures;

fn parse(v: &str) -> f64 {
    v.parse().unwrap_or(f64::NAN)
}

#[test]
fn table1_dstack_faster_than_triton() {
    let d = figures::table1();
    assert_eq!(d.rows.len(), 2);
    let triton = parse(&d.rows[0][1]);
    let dstack = parse(&d.rows[1][1]);
    // Paper: 37% reduction (58.6 s → 35.6 s). Assert >20%.
    assert!(dstack < 0.8 * triton, "triton {triton} dstack {dstack}");
}

#[test]
fn fig9abc_utilization_ordering() {
    let d = figures::fig9abc();
    let util: Vec<f64> = d.rows.iter().map(|r| parse(&r[1])).collect();
    // temporal < plain spatio-temporal < dstack (44% → 60% → 74%).
    assert!(util[0] < util[1] && util[1] < util[2], "{util:?}");
    assert!(util[0] < 55.0, "temporal too high: {}", util[0]);
    assert!(util[2] > 60.0, "dstack too low: {}", util[2]);
}

#[test]
fn fig9d_dstack_near_ideal() {
    let d = figures::fig9d();
    let dstack = d.rows.iter().find(|r| r[0] == "dstack").unwrap();
    let vs_ideal = parse(&dstack[3]);
    // Paper: >90% of ideal. (Ours slightly exceeds 100% — the slotted
    // ideal pays quantization overhead; see EXPERIMENTS.md.)
    assert!(vs_ideal > 90.0, "dstack at {vs_ideal}% of ideal");
    let temporal = d.rows.iter().find(|r| r[0] == "temporal").unwrap();
    assert!(parse(&temporal[3]) < 70.0);
}

#[test]
fn fig10_dstack_beats_temporal_everywhere() {
    let d = figures::fig10();
    let get = |policy: &str| {
        d.rows
            .iter()
            .find(|r| r[0] == format!("{policy} thpt"))
            .map(|r| (1..=4).map(|i| parse(&r[i])).collect::<Vec<_>>())
            .unwrap()
    };
    let temporal = get("temporal");
    let dstack = get("dstack");
    for i in 0..4 {
        assert!(
            dstack[i] > temporal[i],
            "model {i}: dstack {} vs temporal {}",
            dstack[i],
            temporal[i]
        );
    }
    // Light models gain the most (paper: 4x for alexnet/mobilenet).
    assert!(dstack[0] > 2.0 * temporal[0]);
}

#[test]
fn fig11a_dstack_highest_throughput_lowest_violations() {
    let d = figures::fig11a();
    for mix in ["C-4", "C-7"] {
        let rows: Vec<_> = d.rows.iter().filter(|r| r[0] == mix).collect();
        assert_eq!(rows.len(), 5);
        let dstack = rows.iter().find(|r| r[1] == "dstack").unwrap();
        for r in &rows {
            if r[1] == "dstack" {
                continue;
            }
            assert!(
                parse(&dstack[2]) >= parse(&r[2]) * 0.95,
                "{mix}: dstack thpt {} vs {} {}",
                dstack[2],
                r[1],
                r[2]
            );
            assert!(
                parse(&dstack[4]) <= parse(&r[4]) + 0.02,
                "{mix}: dstack viol {} vs {} {}",
                dstack[4],
                r[1],
                r[4]
            );
        }
    }
}

#[test]
fn fig12_cluster_ordering() {
    let d = figures::fig12();
    let total = |p: &str| {
        d.rows.iter().find(|r| r[0].contains(p)).map(|r| parse(&r[1])).unwrap()
    };
    let excl = total("Exclusive");
    let temp = total("Temporal");
    let dstk = total("Dstack");
    assert!(dstk > temp && dstk > 1.3 * excl, "excl {excl} temp {temp} dstack {dstk}");
}

#[test]
fn all_generators_write_csv() {
    let dir = std::env::temp_dir().join("dstack_figs_test");
    for d in figures::generate("tables") {
        d.write_csv(&dir).unwrap();
        assert!(dir.join(format!("{}.csv", d.name)).exists());
    }
}
