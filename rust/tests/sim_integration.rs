//! Cross-module integration: determinism, session accounting and the
//! paper's headline comparisons at the full 10 s scale.

use dstack::config::{build_policy, PolicyKind};
use dstack::sim::{entries_at_optimum, Sim, SimConfig};
use dstack::workload::{merged_stream, slo_proportional_rates, Arrivals};

fn c4_requests(total_rate: f64, horizon_ms: f64, seed: u64) -> (Vec<dstack::sim::ModelEntry>, Vec<dstack::workload::Request>) {
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<_> = names.iter().map(|n| dstack::profile::by_name(n).unwrap()).collect();
    let entries = entries_at_optimum(&profiles);
    let slos: Vec<f64> = profiles.iter().map(|p| p.slo_ms).collect();
    let rates = slo_proportional_rates(total_rate, &slos);
    let specs: Vec<_> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    (entries, merged_stream(&specs, horizon_ms, seed))
}

#[test]
fn full_run_deterministic() {
    let mut reports = Vec::new();
    for _ in 0..2 {
        let (entries, reqs) = c4_requests(1_000.0, 5_000.0, 77);
        let mut pol = build_policy(PolicyKind::Dstack, &entries);
        let mut sim = Sim::new(SimConfig { horizon_ms: 5_000.0, ..Default::default() }, entries);
        reports.push(sim.run(pol.as_mut(), &reqs));
    }
    for i in 0..4 {
        assert_eq!(reports[0].per_model[i].served, reports[1].per_model[i].served);
        assert_eq!(
            reports[0].per_model[i].latencies_ms,
            reports[1].per_model[i].latencies_ms
        );
    }
    assert_eq!(reports[0].busy_ms, reports[1].busy_ms);
}

#[test]
fn headline_dstack_vs_temporal() {
    // §1: "4x improvement in inference throughput" vs temporal at the
    // full 1920 req/s C-4 load. We assert ≥2x here (seeds vary).
    let (entries, reqs) = c4_requests(1_920.0, 10_000.0, 1);
    let mut tpol = build_policy(PolicyKind::Temporal, &entries);
    let mut tsim =
        Sim::new(SimConfig { horizon_ms: 10_000.0, ..Default::default() }, entries.clone());
    let trep = tsim.run(tpol.as_mut(), &reqs);

    let mut dpol = build_policy(PolicyKind::Dstack, &entries);
    let mut dsim = Sim::new(SimConfig { horizon_ms: 10_000.0, ..Default::default() }, entries);
    let drep = dsim.run(dpol.as_mut(), &reqs);

    assert!(
        drep.total_throughput() >= 2.0 * trep.total_throughput(),
        "dstack {} vs temporal {}",
        drep.total_throughput(),
        trep.total_throughput()
    );
    // And utilization improves (paper: ~1.6x).
    assert!(drep.mean_utilization() > 1.2 * trep.mean_utilization());
}

#[test]
fn dstack_violations_lowest_among_policies() {
    let (entries, reqs) = c4_requests(1_500.0, 8_000.0, 5);
    let mut best: Option<(String, f64)> = None;
    let mut dstack_frac = 1.0;
    for kind in [
        PolicyKind::FixedBatch,
        PolicyKind::Temporal,
        PolicyKind::Triton,
        PolicyKind::Gslice,
        PolicyKind::Dstack,
    ] {
        let mut pol = build_policy(kind, &entries);
        let cfg = SimConfig {
            horizon_ms: 8_000.0,
            allow_oversub: kind == PolicyKind::FixedBatch,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg, entries.clone());
        let rep = sim.run(pol.as_mut(), &reqs);
        let frac = rep.violation_fraction();
        if kind == PolicyKind::Dstack {
            dstack_frac = frac;
        }
        if best.as_ref().is_none_or(|(_, b)| frac < *b) {
            best = Some((kind.name().to_string(), frac));
        }
    }
    let (best_name, best_frac) = best.unwrap();
    // Within 2 percentage points of the best policy (GSLICE ties D-STACK
    // at low model counts — the paper observes the same at C-2).
    assert!(
        dstack_frac <= best_frac + 0.02,
        "dstack {dstack_frac} beaten by {best_name} {best_frac}"
    );
}
